//! Explicit per-region lifecycle state machine.
//!
//! Every vFPGA region is always in exactly one [`LifecycleState`];
//! the legal moves between states are closed over
//! [`LifecycleState::can_transition`] and every applied move is
//! recorded in a bounded [`TransitionLog`]. The hypervisor used to
//! re-derive "what is this region doing" from scattered facts
//! (configured? clocked? owned?), which is exactly how a preemption
//! could race an in-flight setup; with the state machine the illegal
//! interleavings are unrepresentable — an attempt to, say, blank a
//! `Programming` region is a typed [`super::DeviceError`] instead of
//! silent corruption.
//!
//! ```text
//!            alloc           PR start         PR done
//!   Free ──────────► Reserved ───────► Programming ───────► Active
//!    ▲                  │  ▲               │                 │  ▲
//!    │          release │  └───────────────┘                 │  │ reprogram
//!    │                  │     PR failed            quiesce   │  │ (via
//!    │◄─────────────────┘                          won       │  │ Programming)
//!    │                                                       ▼  │
//!    │◄────────────── Migrating ◄──────────────────────── Draining
//!    │   source blanked    │        relocation starts        │
//!    │                     └── rollback ──► Active ◄─────────┘
//!    └───────────────────────── release while quiesced ──────┘
//! ```
//!
//! `Draining` and `Migrating` are only ever entered under a won
//! region quiesce (see [`crate::hypervisor::guard`]), so a region can
//! never be observed `Programming` by the relocation path: the pin a
//! programmer holds blocks the quiesce until the PR orchestration is
//! out of the region.

use std::collections::VecDeque;

use crate::util::clock::VirtualTime;
use crate::util::ids::VfpgaId;
use crate::util::json::Json;

/// Lifecycle state of one PR region.
///
/// Declaration order is the canonical index order (`ALL`, gauges).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LifecycleState {
    /// Unowned and blank-or-stale; allocatable.
    Free,
    /// Claimed by an allocation, no PR started yet.
    Reserved,
    /// A partial reconfiguration is in flight.
    Programming,
    /// Holds a configured user design.
    Active,
    /// Quiesce won: no new pins, relocation or teardown imminent.
    Draining,
    /// The design is being relocated off this region.
    Migrating,
}

impl LifecycleState {
    /// Every state, in canonical order.
    pub const ALL: [LifecycleState; 6] = [
        LifecycleState::Free,
        LifecycleState::Reserved,
        LifecycleState::Programming,
        LifecycleState::Active,
        LifecycleState::Draining,
        LifecycleState::Migrating,
    ];

    pub fn name(self) -> &'static str {
        match self {
            LifecycleState::Free => "free",
            LifecycleState::Reserved => "reserved",
            LifecycleState::Programming => "programming",
            LifecycleState::Active => "active",
            LifecycleState::Draining => "draining",
            LifecycleState::Migrating => "migrating",
        }
    }

    pub fn parse(s: &str) -> Option<LifecycleState> {
        LifecycleState::ALL.iter().copied().find(|l| l.name() == s)
    }

    /// The legal-transition relation — the single source of truth the
    /// device validates every move against.
    pub fn can_transition(self, to: LifecycleState) -> bool {
        use LifecycleState::*;
        matches!(
            (self, to),
            // allocation claims a region
            (Free, Reserved)
            // PR orchestration enters the region (first or re-program)
            | (Reserved, Programming)
            | (Active, Programming)
            // PR completes / fails before touching fabric
            | (Programming, Active)
            | (Programming, Reserved)
            // quiesce won ahead of relocation or teardown
            | (Reserved, Draining)
            | (Active, Draining)
            // quiesce released without moving
            | (Draining, Active)
            | (Draining, Reserved)
            // relocation proper
            | (Draining, Migrating)
            | (Migrating, Free)
            // relocation rolled back, design still in place
            | (Migrating, Active)
            // release
            | (Reserved, Free)
            | (Active, Free)
            | (Draining, Free)
        )
    }
}

impl std::fmt::Display for LifecycleState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One applied (already validated) transition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransitionRecord {
    pub region: VfpgaId,
    pub from: LifecycleState,
    pub to: LifecycleState,
    /// Virtual timestamp the transition was applied at.
    pub at: VirtualTime,
}

impl TransitionRecord {
    /// Each record carries both endpoints, so legality is checkable
    /// per record even after older records age out of the log.
    pub fn is_legal(&self) -> bool {
        self.from.can_transition(self.to)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("region", Json::from(self.region.to_string())),
            ("from", Json::from(self.from.name())),
            ("to", Json::from(self.to.name())),
            ("at_s", Json::from(self.at.as_secs_f64())),
        ])
    }
}

/// Newest records kept when the log is full.
pub const TRANSITION_LOG_CAP: usize = 4096;

/// Bounded per-device log of applied transitions (audit + tests).
#[derive(Debug, Default)]
pub struct TransitionLog {
    records: VecDeque<TransitionRecord>,
    dropped: u64,
}

impl TransitionLog {
    pub fn new() -> TransitionLog {
        TransitionLog::default()
    }

    pub fn push(&mut self, rec: TransitionRecord) {
        if self.records.len() == TRANSITION_LOG_CAP {
            self.records.pop_front();
            self.dropped += 1;
        }
        self.records.push_back(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records aged out of the bounded log so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn snapshot(&self) -> Vec<TransitionRecord> {
        self.records.iter().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legal_edges_match_the_diagram() {
        use LifecycleState::*;
        for (from, to) in [
            (Free, Reserved),
            (Reserved, Programming),
            (Programming, Active),
            (Programming, Reserved),
            (Active, Programming),
            (Active, Draining),
            (Reserved, Draining),
            (Draining, Migrating),
            (Draining, Active),
            (Draining, Reserved),
            (Draining, Free),
            (Migrating, Free),
            (Migrating, Active),
            (Reserved, Free),
            (Active, Free),
        ] {
            assert!(from.can_transition(to), "{from} -> {to} must be legal");
        }
    }

    #[test]
    fn illegal_edges_are_rejected() {
        use LifecycleState::*;
        for (from, to) in [
            (Free, Programming),
            (Free, Active),
            (Free, Draining),
            (Free, Migrating),
            (Free, Free),
            (Reserved, Active),
            (Reserved, Migrating),
            (Programming, Free),
            (Programming, Draining),
            (Programming, Migrating),
            (Active, Reserved),
            (Active, Migrating),
            (Migrating, Reserved),
            (Migrating, Draining),
            (Migrating, Programming),
            (Draining, Programming),
        ] {
            assert!(
                !from.can_transition(to),
                "{from} -> {to} must be illegal"
            );
        }
    }

    #[test]
    fn every_state_named_and_parsed() {
        for s in LifecycleState::ALL {
            assert_eq!(LifecycleState::parse(s.name()), Some(s));
        }
        assert_eq!(LifecycleState::parse("broken"), None);
    }

    #[test]
    fn log_caps_and_counts_drops() {
        let mut log = TransitionLog::new();
        let rec = TransitionRecord {
            region: VfpgaId(0),
            from: LifecycleState::Free,
            to: LifecycleState::Reserved,
            at: VirtualTime::ZERO,
        };
        for _ in 0..(TRANSITION_LOG_CAP + 10) {
            log.push(rec);
        }
        assert_eq!(log.len(), TRANSITION_LOG_CAP);
        assert_eq!(log.dropped(), 10);
        assert!(log.snapshot().iter().all(|r| r.is_legal()));
    }

    #[test]
    fn record_json_shape() {
        let rec = TransitionRecord {
            region: VfpgaId(3),
            from: LifecycleState::Active,
            to: LifecycleState::Draining,
            at: VirtualTime::from_secs_f64(2.0),
        };
        let j = rec.to_json();
        assert_eq!(j.get("from").as_str(), Some("active"));
        assert_eq!(j.get("to").as_str(), Some("draining"));
        assert!(rec.is_legal());
    }
}
