//! Partial-reconfiguration regions (the vFPGA substrate).
//!
//! Each physical FPGA is floorplanned into up to four predefined PR
//! regions (Section IV-A: "Each physical FPGA can host up to four
//! virtual FPGAs"). A region has a fixed resource envelope carved out
//! of the device, an explicit [`LifecycleState`] (see
//! [`super::lifecycle`]), the design payload it currently holds, and
//! an independent clock enable (the hypervisor gates clocks of idle
//! regions to save power, Section IV-B).

use super::lifecycle::LifecycleState;
use super::resources::Resources;
use crate::util::ids::VfpgaId;
use crate::util::json::Json;

/// Size classes for vFPGA regions (the RAaaS model offers "vFPGAs of
/// different sizes", Section III-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RegionShape {
    /// 1/4 of the device PR budget (the default paper config).
    Quarter,
    /// 1/2 of the device PR budget.
    Half,
    /// The whole PR budget as one region.
    Full,
}

impl RegionShape {
    /// Fraction of the device's reconfigurable area.
    pub fn fraction(self) -> f64 {
        match self {
            RegionShape::Quarter => 0.25,
            RegionShape::Half => 0.5,
            RegionShape::Full => 1.0,
        }
    }

    /// Number of quarter-slots the shape occupies.
    pub fn quarters(self) -> usize {
        match self {
            RegionShape::Quarter => 1,
            RegionShape::Half => 2,
            RegionShape::Full => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RegionShape::Quarter => "quarter",
            RegionShape::Half => "half",
            RegionShape::Full => "full",
        }
    }

    pub fn parse(s: &str) -> Option<RegionShape> {
        match s {
            "quarter" => Some(RegionShape::Quarter),
            "half" => Some(RegionShape::Half),
            "full" => Some(RegionShape::Full),
            _ => None,
        }
    }
}

/// The design a configured region holds.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionDesign {
    pub bitstream_sha: String,
    pub core: String,
}

/// One PR region on a device.
#[derive(Debug, Clone)]
pub struct Region {
    pub id: VfpgaId,
    pub shape: RegionShape,
    /// Resource envelope available to the user design inside.
    pub capacity: Resources,
    /// Where the region is in its lifecycle. Mutated only through
    /// [`super::FpgaDevice::transition_region`] so every move is
    /// validated and logged.
    pub lifecycle: LifecycleState,
    /// Design payload while configured (orthogonal to the lifecycle:
    /// a `Draining`/`Migrating` region still holds its design).
    pub design: Option<RegionDesign>,
    /// Clock enable — gated off when idle (energy management).
    pub clock_enabled: bool,
}

impl Region {
    pub fn new(id: VfpgaId, shape: RegionShape, capacity: Resources) -> Region {
        Region {
            id,
            shape,
            capacity,
            lifecycle: LifecycleState::Free,
            design: None,
            clock_enabled: false,
        }
    }

    pub fn is_configured(&self) -> bool {
        self.design.is_some()
    }

    /// Blank the region's payload (what PR with a blanking bitstream
    /// does). Lifecycle is driven separately by the device so the
    /// transition is validated and logged.
    pub fn clear(&mut self) {
        self.design = None;
        self.clock_enabled = false;
    }

    pub fn to_json(&self) -> Json {
        let state = match &self.design {
            None => Json::from("empty"),
            Some(d) => Json::obj(vec![
                ("bitstream_sha", Json::from(d.bitstream_sha.as_str())),
                ("core", Json::from(d.core.as_str())),
            ]),
        };
        Json::obj(vec![
            ("id", Json::from(self.id.to_string())),
            ("shape", Json::from(self.shape.name())),
            ("capacity", self.capacity.to_json()),
            ("state", state),
            ("lifecycle", Json::from(self.lifecycle.name())),
            ("clock_enabled", Json::from(self.clock_enabled)),
        ])
    }
}

/// Compute the per-region envelope for `n` equal regions on a board
/// whose *reconfigurable* budget is the device minus the static
/// (RC2F) design footprint.
pub fn equal_split(budget: Resources, n: usize) -> Resources {
    let n = n as u64;
    Resources::new(
        budget.lut / n,
        budget.ff / n,
        budget.bram / n,
        budget.dsp / n,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_fractions() {
        assert_eq!(RegionShape::Quarter.fraction(), 0.25);
        assert_eq!(RegionShape::Half.quarters(), 2);
        assert_eq!(RegionShape::Full.quarters(), 4);
        assert_eq!(RegionShape::parse("half"), Some(RegionShape::Half));
        assert_eq!(RegionShape::parse("eighth"), None);
    }

    #[test]
    fn payload_lifecycle() {
        let mut r = Region::new(
            VfpgaId(0),
            RegionShape::Quarter,
            Resources::new(100, 100, 10, 10),
        );
        assert!(!r.is_configured());
        assert_eq!(r.lifecycle, LifecycleState::Free);
        r.design = Some(RegionDesign {
            bitstream_sha: "abc".into(),
            core: "matmul16".into(),
        });
        r.clock_enabled = true;
        assert!(r.is_configured());
        r.clear();
        assert!(!r.is_configured());
        assert!(!r.clock_enabled);
    }

    #[test]
    fn equal_split_divides() {
        let budget = Resources::new(100, 200, 40, 80);
        let q = equal_split(budget, 4);
        assert_eq!(q, Resources::new(25, 50, 10, 20));
        // n regions never exceed the budget
        assert!(q.times(4).fits_in(budget));
    }

    #[test]
    fn json_shape() {
        let r = Region::new(
            VfpgaId(3),
            RegionShape::Half,
            Resources::new(1, 2, 3, 4),
        );
        let j = r.to_json();
        assert_eq!(j.get("id").as_str().unwrap(), "vfpga-3");
        assert_eq!(j.get("shape").as_str().unwrap(), "half");
        assert_eq!(j.get("state").as_str().unwrap(), "empty");
        assert_eq!(j.get("lifecycle").as_str().unwrap(), "free");
    }
}
