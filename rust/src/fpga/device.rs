//! The physical FPGA device: regions + configuration ports + power.
//!
//! Owns the timed operations of Table I:
//! * full configuration via JTAG/USB (28.370 s on the VC707),
//! * partial reconfiguration of one region (732 ms for a quarter
//!   region, scaled by region size),
//! and the clock-gating hooks the hypervisor's energy manager uses.
//!
//! The device is also the authority over every region's
//! [`LifecycleState`]: all moves go through
//! [`FpgaDevice::transition_region`], which validates them against
//! [`LifecycleState::can_transition`] and appends them to a bounded
//! [`TransitionLog`] — an illegal move is a typed
//! [`DeviceError::IllegalTransition`], never silent state damage.
//!
//! PCIe link-parameter save/restore (hot-plug after a full
//! reconfiguration, Section IV-C) lives here too: a full bitstream
//! replaces the PCIe endpoint, so the hypervisor snapshots the link
//! parameters first and restores them afterwards.

use std::sync::Arc;

use super::board::BoardSpec;
use super::lifecycle::{
    LifecycleState, TransitionLog, TransitionRecord,
};
use super::power::{EnergyMeter, PowerState};
use super::region::{equal_split, Region, RegionDesign, RegionShape};
use super::resources::Resources;
use crate::bitstream::{Bitstream, BitstreamKind};
use crate::util::clock::{VirtualClock, VirtualTime};
use crate::util::ids::{FpgaId, VfpgaId};
use crate::util::json::Json;

/// Which configuration port an operation uses (affects timing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConfigPort {
    /// External JTAG/USB cable — slow, used for full bitstreams
    /// (Table I footnote: "Configuration using JTAG and USB").
    Jtag,
    /// Internal configuration access port — fast, used for PR.
    Icap,
}

/// Errors raised by device operations.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum DeviceError {
    #[error("bitstream targets part '{bitstream}' but device is '{device}'")]
    WrongPart { bitstream: String, device: String },
    #[error("region {0} not present on device")]
    NoSuchRegion(VfpgaId),
    #[error("bitstream is {kind:?} but operation needs {needed:?}")]
    WrongKind {
        kind: BitstreamKind,
        needed: BitstreamKind,
    },
    #[error("design needs {needed} but region offers {offered}")]
    DoesNotFit { needed: String, offered: String },
    #[error("device has no static (RC2F) design loaded")]
    NoStaticDesign,
    #[error("bitstream failed sanity check: {0}")]
    Insane(String),
    #[error("illegal lifecycle transition {from} -> {to} on {region}")]
    IllegalTransition {
        region: VfpgaId,
        from: LifecycleState,
        to: LifecycleState,
    },
}

/// Status snapshot (what the RC2F status call returns).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceStatus {
    pub fpga: FpgaId,
    pub board: &'static str,
    pub static_design: Option<String>,
    pub regions_total: usize,
    pub regions_configured: usize,
    pub regions_clocked: usize,
    /// Regions quiesced ahead of relocation/teardown.
    pub regions_draining: usize,
    /// Regions whose design is being relocated right now.
    pub regions_migrating: usize,
    pub power_w: f64,
}

/// One physical FPGA board attached to a node.
#[derive(Debug)]
pub struct FpgaDevice {
    pub id: FpgaId,
    pub board: BoardSpec,
    clock: Arc<VirtualClock>,
    /// Name+sha of the loaded static design (None right after power-on).
    static_design: Option<(String, String)>,
    /// Static design footprint (subtracted from the PR budget).
    static_footprint: Resources,
    regions: Vec<Region>,
    energy: EnergyMeter,
    /// Saved PCIe link parameters for hot-plug restore.
    saved_link: Option<crate::pcie::LinkParams>,
    /// Applied lifecycle transitions, newest-kept (audit + tests).
    log: TransitionLog,
    /// Transition counters land here when wired (set at boot).
    metrics: Option<Arc<crate::metrics::Registry>>,
    /// Live transition events land here when wired (the middleware
    /// server fans them to `subscribe` clients).
    transition_sink: Option<SinkFn>,
}

/// Callback invoked on every validated lifecycle transition. Runs
/// under the device lock: keep it cheap and never call back into the
/// device.
pub type TransitionSink =
    Arc<dyn Fn(FpgaId, &TransitionRecord) + Send + Sync>;

/// Debug-opaque wrapper so the closure can live inside the
/// `#[derive(Debug)]` device.
struct SinkFn(TransitionSink);

impl std::fmt::Debug for SinkFn {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("TransitionSink(..)")
    }
}

impl FpgaDevice {
    pub fn new(
        id: FpgaId,
        board: BoardSpec,
        clock: Arc<VirtualClock>,
    ) -> FpgaDevice {
        let power = PowerState {
            base_w: board.static_power_w,
            idle_w: board.idle_power_w,
            active_regions: 0,
            region_w: board.active_region_power_w,
        };
        let energy = EnergyMeter::new(Arc::clone(&clock), power);
        FpgaDevice {
            id,
            board,
            clock,
            static_design: None,
            static_footprint: Resources::ZERO,
            regions: Vec::new(),
            energy,
            saved_link: None,
            log: TransitionLog::new(),
            metrics: None,
            transition_sink: None,
        }
    }

    /// Wire a metrics registry so transitions bump
    /// `region.transitions` / `region.transition.<from>_to_<to>`.
    pub fn set_metrics(&mut self, metrics: Arc<crate::metrics::Registry>) {
        self.metrics = Some(metrics);
    }

    /// Wire a live transition event sink (protocol-3 `region` topic).
    pub fn set_transition_sink(&mut self, sink: TransitionSink) {
        self.transition_sink = Some(SinkFn(sink));
    }

    // ------------------------------------------------------ accessors

    pub fn regions(&self) -> &[Region] {
        &self.regions
    }

    pub fn region(&self, id: VfpgaId) -> Result<&Region, DeviceError> {
        self.regions
            .iter()
            .find(|r| r.id == id)
            .ok_or(DeviceError::NoSuchRegion(id))
    }

    fn region_mut(&mut self, id: VfpgaId) -> Result<&mut Region, DeviceError> {
        self.regions
            .iter_mut()
            .find(|r| r.id == id)
            .ok_or(DeviceError::NoSuchRegion(id))
    }

    pub fn has_static_design(&self) -> bool {
        self.static_design.is_some()
    }

    pub fn static_design_name(&self) -> Option<&str> {
        self.static_design.as_ref().map(|(n, _)| n.as_str())
    }

    /// Status snapshot — the payload of the RC2F status call.
    pub fn status(&self) -> DeviceStatus {
        DeviceStatus {
            fpga: self.id,
            board: self.board.kind.name(),
            static_design: self.static_design.as_ref().map(|(n, _)| n.clone()),
            regions_total: self.regions.len(),
            regions_configured: self
                .regions
                .iter()
                .filter(|r| r.is_configured())
                .count(),
            regions_clocked: self.clocked_regions(),
            regions_draining: self
                .lifecycle_count(LifecycleState::Draining),
            regions_migrating: self
                .lifecycle_count(LifecycleState::Migrating),
            power_w: self.energy.draw_w(),
        }
    }

    pub fn clocked_regions(&self) -> usize {
        self.regions.iter().filter(|r| r.clock_enabled).count()
    }

    /// Regions currently in `state`.
    pub fn lifecycle_count(&self, state: LifecycleState) -> usize {
        self.regions.iter().filter(|r| r.lifecycle == state).count()
    }

    /// Integrated energy so far (virtual time).
    pub fn energy_joules(&mut self) -> f64 {
        self.energy.joules()
    }

    // ------------------------------------------------------ lifecycle

    /// Apply one validated lifecycle transition and log it. Returns
    /// the state the region came from (callers roll back with it).
    pub fn transition_region(
        &mut self,
        region_id: VfpgaId,
        to: LifecycleState,
    ) -> Result<LifecycleState, DeviceError> {
        let at = self.clock.now();
        let region = self.region_mut(region_id)?;
        let from = region.lifecycle;
        if !from.can_transition(to) {
            return Err(DeviceError::IllegalTransition {
                region: region_id,
                from,
                to,
            });
        }
        region.lifecycle = to;
        let rec = TransitionRecord {
            region: region_id,
            from,
            to,
            at,
        };
        self.log.push(rec);
        if let Some(sink) = &self.transition_sink {
            (sink.0)(self.id, &rec);
        }
        if let Some(m) = &self.metrics {
            m.counter("region.transitions").inc();
            m.counter(&format!(
                "region.transition.{}_to_{}",
                from.name(),
                to.name()
            ))
            .inc();
        }
        Ok(from)
    }

    /// Snapshot of the applied-transition log.
    pub fn transition_log(&self) -> Vec<TransitionRecord> {
        self.log.snapshot()
    }

    /// Records aged out of the bounded transition log so far.
    pub fn transition_log_dropped(&self) -> u64 {
        self.log.dropped()
    }

    // --------------------------------------------- full configuration

    /// Load a *full* bitstream (RSaaS user design or the RC2F static
    /// design). Charges the JTAG configuration time from Table I and
    /// wipes all regions (a full bitstream replaces everything).
    ///
    /// Returns the charged virtual duration.
    pub fn configure_full(
        &mut self,
        bs: &Bitstream,
    ) -> Result<VirtualTime, DeviceError> {
        self.check_part(bs)?;
        if bs.kind != BitstreamKind::Full {
            return Err(DeviceError::WrongKind {
                kind: bs.kind,
                needed: BitstreamKind::Full,
            });
        }
        let d = VirtualTime::from_secs_f64(self.board.jtag_config_s);
        self.clock.advance(d);
        self.regions.clear();
        self.static_design = Some((bs.meta.core.clone(), bs.sha256.clone()));
        self.static_footprint = bs.meta.resources;
        // If this is an RC2F basic design, carve out its vFPGA regions.
        if let Some(n) = bs.meta.vfpga_regions {
            self.carve_regions(n);
        }
        self.energy.set_active_regions(0);
        Ok(d)
    }

    /// Floorplan `n` equal quarter/half/full regions out of the PR
    /// budget (device minus static footprint). Region ids are derived
    /// from the device id so they are cluster-unique.
    fn carve_regions(&mut self, n: usize) {
        assert!(n >= 1 && n <= crate::paper::MAX_VFPGAS);
        // Keep a 20% routing/clocking margin like a real floorplan.
        let free = self.board.resources.minus(self.static_footprint);
        let budget = Resources::new(
            free.lut * 8 / 10,
            free.ff * 8 / 10,
            free.bram * 8 / 10,
            free.dsp * 8 / 10,
        );
        let per = equal_split(budget, n);
        let shape = match n {
            1 => RegionShape::Full,
            2 => RegionShape::Half,
            _ => RegionShape::Quarter,
        };
        self.regions = (0..n)
            .map(|i| {
                Region::new(
                    VfpgaId(self.id.0 * crate::paper::MAX_VFPGAS as u64 + i as u64),
                    shape,
                    per,
                )
            })
            .collect();
    }

    // ------------------------------------------ partial reconfiguration

    /// Partially reconfigure one region with a user design. Charges
    /// the ICAP PR time from Table I, scaled by the region's share of
    /// the device. Requires the RC2F static design to be present.
    ///
    /// Drives the region's lifecycle through `Programming -> Active`.
    /// A `Free` region is claimed (`Free -> Reserved`) on the way in —
    /// that is two legal transitions, not a bypass — so device-level
    /// callers (tests, benches) need no separate allocation step. A
    /// `Draining`/`Migrating` region rejects the PR with
    /// [`DeviceError::IllegalTransition`].
    pub fn configure_partial(
        &mut self,
        region_id: VfpgaId,
        bs: &Bitstream,
    ) -> Result<VirtualTime, DeviceError> {
        self.check_part(bs)?;
        if self.static_design.is_none() {
            return Err(DeviceError::NoStaticDesign);
        }
        let BitstreamKind::Partial = bs.kind else {
            return Err(DeviceError::WrongKind {
                kind: bs.kind,
                needed: BitstreamKind::Partial,
            });
        };
        let pr_ms = {
            let region = self.region(region_id)?;
            if !bs.meta.resources.fits_in(region.capacity) {
                return Err(DeviceError::DoesNotFit {
                    needed: bs.meta.resources.to_string(),
                    offered: region.capacity.to_string(),
                });
            }
            // PR time scales with configured area: a quarter region is
            // the paper's measured 732 ms.
            self.board.pr_quarter_region_ms
                * (region.shape.fraction() / 0.25)
        };
        if self.region(region_id)?.lifecycle == LifecycleState::Free {
            self.transition_region(region_id, LifecycleState::Reserved)?;
        }
        if self.region(region_id)?.lifecycle != LifecycleState::Programming
        {
            self.transition_region(region_id, LifecycleState::Programming)?;
        }
        let d = VirtualTime::from_millis_f64(pr_ms);
        self.clock.advance(d);
        let design = RegionDesign {
            bitstream_sha: bs.sha256.clone(),
            core: bs.meta.core.clone(),
        };
        {
            let region = self.region_mut(region_id)?;
            region.design = Some(design);
            region.clock_enabled = true;
        }
        self.transition_region(region_id, LifecycleState::Active)?;
        let active = self.clocked_regions();
        self.energy.set_active_regions(active);
        Ok(d)
    }

    /// Blank a region (PR with the blanking bitstream) and gate its
    /// clock. Charged like a PR operation. Transitions the region to
    /// `Free`; blanking a `Programming` region is illegal (the PR
    /// orchestration owns it — quiesce first).
    pub fn clear_region(
        &mut self,
        region_id: VfpgaId,
    ) -> Result<VirtualTime, DeviceError> {
        let pr_ms = {
            let region = self.region(region_id)?;
            self.board.pr_quarter_region_ms
                * (region.shape.fraction() / 0.25)
        };
        if self.region(region_id)?.lifecycle != LifecycleState::Free {
            self.transition_region(region_id, LifecycleState::Free)?;
        }
        let d = VirtualTime::from_millis_f64(pr_ms);
        self.clock.advance(d);
        self.region_mut(region_id)?.clear();
        let active = self.clocked_regions();
        self.energy.set_active_regions(active);
        Ok(d)
    }

    /// Gate/ungate a region clock without reconfiguring (idle power
    /// management; instantaneous from the host's perspective).
    pub fn set_region_clock(
        &mut self,
        region_id: VfpgaId,
        enabled: bool,
    ) -> Result<(), DeviceError> {
        self.region_mut(region_id)?.clock_enabled = enabled;
        let active = self.clocked_regions();
        self.energy.set_active_regions(active);
        Ok(())
    }

    // ------------------------------------------------- PCIe hot-plug

    /// Snapshot link parameters before a full reconfiguration.
    pub fn save_link_params(&mut self, params: crate::pcie::LinkParams) {
        self.saved_link = Some(params);
    }

    /// Restore the snapshot after reconfiguration (hot-plug).
    pub fn restore_link_params(&mut self) -> Option<crate::pcie::LinkParams> {
        self.saved_link
    }

    fn check_part(&self, bs: &Bitstream) -> Result<(), DeviceError> {
        if bs.meta.part != self.board.part {
            return Err(DeviceError::WrongPart {
                bitstream: bs.meta.part.clone(),
                device: self.board.part.to_string(),
            });
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::from(self.id.to_string())),
            ("board", self.board.to_json()),
            (
                "static_design",
                match &self.static_design {
                    Some((n, sha)) => Json::obj(vec![
                        ("name", Json::from(n.as_str())),
                        ("sha256", Json::from(sha.as_str())),
                    ]),
                    None => Json::Null,
                },
            ),
            (
                "regions",
                Json::Arr(self.regions.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::tests_support::{partial_bs, rc2f_full_bs};

    fn device() -> (FpgaDevice, Arc<VirtualClock>) {
        let clock = VirtualClock::new();
        (
            FpgaDevice::new(FpgaId(0), BoardSpec::vc707(), Arc::clone(&clock)),
            clock,
        )
    }

    #[test]
    fn full_configuration_charges_table1_time() {
        let (mut dev, clock) = device();
        let bs = rc2f_full_bs("xc7vx485t", 4);
        let d = dev.configure_full(&bs).unwrap();
        assert!((d.as_secs_f64() - 28.370).abs() < 1e-6);
        assert!((clock.now().as_secs_f64() - 28.370).abs() < 1e-6);
        assert_eq!(dev.regions().len(), 4);
        assert!(dev.has_static_design());
        assert!(dev
            .regions()
            .iter()
            .all(|r| r.lifecycle == LifecycleState::Free));
    }

    #[test]
    fn partial_reconfiguration_charges_732ms() {
        let (mut dev, clock) = device();
        dev.configure_full(&rc2f_full_bs("xc7vx485t", 4)).unwrap();
        let t0 = clock.now();
        let region = dev.regions()[0].id;
        let d = dev
            .configure_partial(region, &partial_bs("xc7vx485t", "matmul16"))
            .unwrap();
        assert!((d.as_millis_f64() - 732.0).abs() < 1e-6);
        assert!(
            (clock.since(t0).as_millis_f64() - 732.0).abs() < 1e-6
        );
        assert!(dev.region(region).unwrap().is_configured());
        assert_eq!(
            dev.region(region).unwrap().lifecycle,
            LifecycleState::Active
        );
    }

    #[test]
    fn pr_scales_with_region_shape() {
        let (mut dev, _clock) = device();
        dev.configure_full(&rc2f_full_bs("xc7vx485t", 2)).unwrap();
        let region = dev.regions()[0].id;
        let d = dev
            .configure_partial(region, &partial_bs("xc7vx485t", "matmul32"))
            .unwrap();
        // Half region = 2x the quarter-region PR time.
        assert!((d.as_millis_f64() - 1464.0).abs() < 1e-6);
    }

    #[test]
    fn pr_requires_static_design() {
        let (mut dev, _) = device();
        let err = dev
            .configure_partial(VfpgaId(0), &partial_bs("xc7vx485t", "m"))
            .unwrap_err();
        assert_eq!(err, DeviceError::NoStaticDesign);
    }

    #[test]
    fn wrong_part_rejected() {
        let (mut dev, _) = device();
        let err = dev
            .configure_full(&rc2f_full_bs("xc6vlx240t", 4))
            .unwrap_err();
        assert!(matches!(err, DeviceError::WrongPart { .. }));
    }

    #[test]
    fn wrong_kind_rejected() {
        let (mut dev, _) = device();
        dev.configure_full(&rc2f_full_bs("xc7vx485t", 4)).unwrap();
        let region = dev.regions()[0].id;
        let err = dev
            .configure_partial(region, &rc2f_full_bs("xc7vx485t", 4))
            .unwrap_err();
        assert!(matches!(err, DeviceError::WrongKind { .. }));
        let err2 = dev
            .configure_full(&partial_bs("xc7vx485t", "m"))
            .unwrap_err();
        assert!(matches!(err2, DeviceError::WrongKind { .. }));
    }

    #[test]
    fn oversized_design_rejected() {
        let (mut dev, _) = device();
        dev.configure_full(&rc2f_full_bs("xc7vx485t", 4)).unwrap();
        let region = dev.regions()[0].id;
        let mut bs = partial_bs("xc7vx485t", "huge");
        bs.meta.resources = Resources::new(10_000_000, 0, 0, 0);
        let err = dev.configure_partial(region, &bs).unwrap_err();
        assert!(matches!(err, DeviceError::DoesNotFit { .. }));
        // The rejected PR never entered the state machine.
        assert_eq!(
            dev.region(region).unwrap().lifecycle,
            LifecycleState::Free
        );
    }

    #[test]
    fn regions_fit_device_budget() {
        let (mut dev, _) = device();
        dev.configure_full(&rc2f_full_bs("xc7vx485t", 4)).unwrap();
        let total = dev
            .regions()
            .iter()
            .fold(Resources::ZERO, |acc, r| acc.plus(r.capacity));
        assert!(total
            .plus(Resources::new(8532, 8318, 25, 0))
            .fits_in(dev.board.resources));
    }

    #[test]
    fn clock_gating_updates_power() {
        let (mut dev, _) = device();
        dev.configure_full(&rc2f_full_bs("xc7vx485t", 4)).unwrap();
        let idle = dev.status().power_w;
        let region = dev.regions()[0].id;
        dev.configure_partial(region, &partial_bs("xc7vx485t", "m"))
            .unwrap();
        let active = dev.status().power_w;
        assert!(active > idle);
        dev.set_region_clock(region, false).unwrap();
        assert_eq!(dev.status().power_w, idle);
    }

    #[test]
    fn clear_region_blanks_and_charges() {
        let (mut dev, clock) = device();
        dev.configure_full(&rc2f_full_bs("xc7vx485t", 4)).unwrap();
        let region = dev.regions()[0].id;
        dev.configure_partial(region, &partial_bs("xc7vx485t", "m"))
            .unwrap();
        let t0 = clock.now();
        dev.clear_region(region).unwrap();
        assert!(!dev.region(region).unwrap().is_configured());
        assert_eq!(
            dev.region(region).unwrap().lifecycle,
            LifecycleState::Free
        );
        assert!(clock.since(t0).as_millis_f64() > 0.0);
    }

    #[test]
    fn hotplug_roundtrip() {
        let (mut dev, _) = device();
        let params = crate::pcie::LinkParams::gen2_x4();
        dev.save_link_params(params);
        assert_eq!(dev.restore_link_params(), Some(params));
    }

    #[test]
    fn status_counts() {
        let (mut dev, _) = device();
        dev.configure_full(&rc2f_full_bs("xc7vx485t", 4)).unwrap();
        let r0 = dev.regions()[0].id;
        let r1 = dev.regions()[1].id;
        dev.configure_partial(r0, &partial_bs("xc7vx485t", "a"))
            .unwrap();
        dev.configure_partial(r1, &partial_bs("xc7vx485t", "b"))
            .unwrap();
        dev.set_region_clock(r1, false).unwrap();
        let st = dev.status();
        assert_eq!(st.regions_total, 4);
        assert_eq!(st.regions_configured, 2);
        assert_eq!(st.regions_clocked, 1);
        assert_eq!(st.regions_draining, 0);
        assert_eq!(st.regions_migrating, 0);
        dev.transition_region(r0, LifecycleState::Draining).unwrap();
        assert_eq!(dev.status().regions_draining, 1);
        dev.transition_region(r0, LifecycleState::Migrating).unwrap();
        let st = dev.status();
        assert_eq!(st.regions_draining, 0);
        assert_eq!(st.regions_migrating, 1);
    }

    #[test]
    fn full_reconfig_wipes_regions() {
        let (mut dev, _) = device();
        dev.configure_full(&rc2f_full_bs("xc7vx485t", 4)).unwrap();
        let r0 = dev.regions()[0].id;
        dev.configure_partial(r0, &partial_bs("xc7vx485t", "a"))
            .unwrap();
        dev.configure_full(&rc2f_full_bs("xc7vx485t", 2)).unwrap();
        assert_eq!(dev.regions().len(), 2);
        assert!(dev.regions().iter().all(|r| !r.is_configured()));
    }

    #[test]
    fn illegal_transitions_are_typed_errors() {
        let (mut dev, _) = device();
        dev.configure_full(&rc2f_full_bs("xc7vx485t", 4)).unwrap();
        let r0 = dev.regions()[0].id;
        // Free -> Active skips Reserved/Programming: illegal.
        let err = dev
            .transition_region(r0, LifecycleState::Active)
            .unwrap_err();
        assert!(matches!(err, DeviceError::IllegalTransition { .. }));
        // A quiesced region rejects PR...
        dev.transition_region(r0, LifecycleState::Reserved).unwrap();
        dev.transition_region(r0, LifecycleState::Draining).unwrap();
        let err = dev
            .configure_partial(r0, &partial_bs("xc7vx485t", "m"))
            .unwrap_err();
        assert!(matches!(err, DeviceError::IllegalTransition { .. }));
        // ...and a Programming region rejects blanking.
        dev.transition_region(r0, LifecycleState::Reserved).unwrap();
        dev.transition_region(r0, LifecycleState::Programming)
            .unwrap();
        let err = dev.clear_region(r0).unwrap_err();
        assert!(matches!(err, DeviceError::IllegalTransition { .. }));
    }

    #[test]
    fn transition_log_records_only_legal_moves() {
        let (mut dev, _) = device();
        dev.configure_full(&rc2f_full_bs("xc7vx485t", 4)).unwrap();
        let r0 = dev.regions()[0].id;
        dev.configure_partial(r0, &partial_bs("xc7vx485t", "a"))
            .unwrap();
        // Rejected moves leave no trace.
        let _ = dev.transition_region(r0, LifecycleState::Reserved);
        dev.clear_region(r0).unwrap();
        let log = dev.transition_log();
        // Free->Reserved, Reserved->Programming, Programming->Active,
        // Active->Free.
        assert_eq!(log.len(), 4);
        assert!(log.iter().all(|r| r.is_legal()));
        assert_eq!(log[0].from, LifecycleState::Free);
        assert_eq!(log[3].to, LifecycleState::Free);
    }
}
