//! Simulated FPGA device substrate.
//!
//! The paper's testbed is two nodes with Xilinx ML605 / VC707 boards
//! (Section IV-A). We do not have that hardware, so this module
//! implements the device model the rest of the stack manages:
//! resource inventories, partial-reconfiguration regions, timed
//! configuration ports (JTAG full configuration, ICAP partial
//! reconfiguration), clock gating and a power/energy model.
//!
//! Everything time-like is charged to the shared
//! [`crate::util::clock::VirtualClock`], calibrated to Table I of the
//! paper; see DESIGN.md §3 for the substitution argument.

pub mod board;
pub mod device;
pub mod lifecycle;
pub mod power;
pub mod region;
pub mod resources;

pub use board::{BoardKind, BoardSpec};
pub use device::{
    ConfigPort, DeviceError, DeviceStatus, FpgaDevice, TransitionSink,
};
pub use lifecycle::{LifecycleState, TransitionLog, TransitionRecord};
pub use power::{EnergyMeter, PowerState};
pub use region::{Region, RegionDesign, RegionShape};
pub use resources::Resources;
