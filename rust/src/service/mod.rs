//! The three cloud service-model façades (Section III).
//!
//! These are the *user-visible* surfaces; each wraps the scheduler's
//! unified admission API with exactly the rights and visibility its
//! model grants:
//!
//! * [`RsaasService`] — full physical FPGAs (optionally inside a VM),
//!   full-bitstream freedom, the whole design flow as a cloud service;
//! * [`RaaasService`] — vFPGAs behind the RC2F framework only: users
//!   see sizes, allocate (singly or as an atomic gang for multi-core
//!   designs), program *partial* bitfiles through the sanity checker,
//!   and stream through the host API;
//! * [`BaaasService`] — no FPGA visibility at all: users see named
//!   services; allocation, PR and streaming happen in the background
//!   with provider bitfiles.
//!
//! Every allocation is an [`AdmissionRequest`] admitted through the
//! cluster [`Scheduler`] ([`crate::sched`]) and returns a capability
//! [`Lease`] — quota, fair-share and reservation checks apply
//! uniformly, and the lease handle itself carries the
//! `program`/`stream`/`release` surface (placement is re-resolved
//! through the lease, so migrations are transparent). Interactive
//! façade calls (RAaaS/RSaaS leases) use the non-blocking fast path
//! and may preempt batch leases; BAaaS invocations are background
//! work and admit at batch class. Setup and streaming hold region
//! pins, and preemption only displaces quiescable victims, so a
//! preemption can no longer race an invocation's in-flight setup —
//! [`with_preemption_retry`] remains wrapped around the provider-side
//! body purely as defense in depth (a triggered retry bumps
//! `sched.preempt.raced`, asserted 0 by the invariants suite).

use std::sync::Arc;

use crate::bitstream::Bitstream;
use crate::config::ServiceModel;
use crate::hypervisor::{Hypervisor, HypervisorError};
use crate::rc2f::stream::{StreamConfig, StreamOutcome};
use crate::sched::{
    with_preemption_retry, AdmissionRequest, Lease, RequestClass,
    Scheduler,
};
use crate::util::ids::UserId;

/// RAaaS: vFPGA leases + framework streaming.
pub struct RaaasService {
    pub hv: Arc<Hypervisor>,
    pub sched: Arc<Scheduler>,
}

impl RaaasService {
    /// Stand-alone façade with its own scheduler.
    pub fn new(hv: Arc<Hypervisor>) -> RaaasService {
        let sched = Scheduler::new(Arc::clone(&hv));
        RaaasService { hv, sched }
    }

    /// Share one cluster scheduler across façades (quotas and
    /// fair-share then apply across all service models).
    pub fn with_scheduler(sched: Arc<Scheduler>) -> RaaasService {
        RaaasService {
            hv: Arc::clone(sched.hv()),
            sched,
        }
    }

    /// Lease one vFPGA. The lease exposes the vFPGA id — but not the
    /// physical slot; bitfiles are retargeted transparently.
    pub fn alloc(&self, user: UserId) -> Result<Lease, HypervisorError> {
        self.sched
            .admit(&AdmissionRequest::new(
                user,
                ServiceModel::RAaaS,
                RequestClass::Interactive,
            ))
            .map_err(HypervisorError::from)
    }

    /// Lease `n` vFPGAs atomically (multi-core designs): all regions
    /// grant together or the request fails — no partial gang is ever
    /// held.
    pub fn alloc_gang(
        &self,
        user: UserId,
        n: u32,
    ) -> Result<Lease, HypervisorError> {
        self.sched
            .admit(
                &AdmissionRequest::new(
                    user,
                    ServiceModel::RAaaS,
                    RequestClass::Interactive,
                )
                .gang(n),
            )
            .map_err(HypervisorError::from)
    }
}

/// RSaaS: whole physical devices.
pub struct RsaasService {
    pub hv: Arc<Hypervisor>,
    pub sched: Arc<Scheduler>,
}

impl RsaasService {
    pub fn new(hv: Arc<Hypervisor>) -> RsaasService {
        let sched = Scheduler::new(Arc::clone(&hv));
        RsaasService { hv, sched }
    }

    pub fn with_scheduler(sched: Arc<Scheduler>) -> RsaasService {
        RsaasService {
            hv: Arc::clone(sched.hv()),
            sched,
        }
    }

    /// Lease a full physical FPGA. The returned lease exposes
    /// [`Lease::program_full`] for full-bitstream configuration.
    pub fn alloc(&self, user: UserId) -> Result<Lease, HypervisorError> {
        self.sched
            .admit(&AdmissionRequest::physical(
                user,
                RequestClass::Interactive,
            ))
            .map_err(HypervisorError::from)
    }
}

/// BAaaS: named provider services, FPGAs invisible.
pub struct BaaasService {
    pub hv: Arc<Hypervisor>,
    pub sched: Arc<Scheduler>,
}

impl BaaasService {
    pub fn new(hv: Arc<Hypervisor>) -> BaaasService {
        let sched = Scheduler::new(Arc::clone(&hv));
        BaaasService { hv, sched }
    }

    pub fn with_scheduler(sched: Arc<Scheduler>) -> BaaasService {
        BaaasService {
            hv: Arc::clone(sched.hv()),
            sched,
        }
    }

    /// What end users see: the service catalogue.
    pub fn catalogue(&self) -> Vec<String> {
        self.hv.service_names()
    }

    /// Invoke a service: the provider allocates a vFPGA in the
    /// background (batch class — preemptable by interactive leases),
    /// programs the prebuilt bitfile, streams, releases. The caller
    /// never sees device ids.
    ///
    /// Setup and streaming pin the region, so a preemption waits its
    /// turn (or picks another victim) instead of racing this
    /// invocation mid-flight.
    pub fn invoke(
        &self,
        user: UserId,
        service: &str,
        cfg: &StreamConfig,
    ) -> Result<StreamOutcome, HypervisorError> {
        let bitfile = self.hv.service_bitfile(service)?;
        let lease = self
            .sched
            .admit(&AdmissionRequest::new(
                user,
                ServiceModel::BAaaS,
                RequestClass::Batch,
            ))
            .map_err(HypervisorError::from)?;
        let result = run_setup_and_stream(&lease, &bitfile, cfg);
        // Always release, success or failure.
        let _ = lease.release();
        result
    }
}

/// The provider-side program+stream body shared by BAaaS invocations
/// and inline batch workers. The one-shot preemption retry around it
/// is defense in depth only — program/stream hold region pins, so
/// the race it absorbs is structurally impossible (`sched.preempt.
/// raced` counts any trigger and stays 0).
pub fn run_setup_and_stream(
    lease: &Lease,
    bitfile: &Bitstream,
    cfg: &StreamConfig,
) -> Result<StreamOutcome, HypervisorError> {
    with_preemption_retry(lease, || {
        lease.program(bitfile)?;
        lease.stream_direct(cfg)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn hv() -> Arc<Hypervisor> {
        Arc::new(Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap())
    }

    fn mm16_bitfile() -> Bitstream {
        crate::testing::mm16_partial(0)
    }

    #[test]
    fn raaas_end_to_end() {
        if !crate::testing::artifacts_available("service::raaas_end_to_end") {
            return;
        }
        let svc = RaaasService::new(hv());
        let user = svc.hv.add_user("alice");
        let lease = svc.alloc(user).unwrap();
        lease.program(&mm16_bitfile()).unwrap();
        let out = lease.stream(&StreamConfig::matmul16(512)).unwrap();
        assert_eq!(out.validation_failures, 0);
        lease.release().unwrap();
    }

    #[test]
    fn raaas_program_retargets_foreign_slot_bitfile() {
        let svc = RaaasService::new(hv());
        let user = svc.hv.add_user("alice");
        // Fill slot 0 so the next lease lands on slot 1 — the bitfile
        // below still targets slot 0's window and must be retargeted.
        let l0 = svc.alloc(user).unwrap();
        let l1 = svc.alloc(user).unwrap();
        l0.program(&mm16_bitfile()).unwrap();
        l1.program(&mm16_bitfile()).unwrap(); // would fail unretargeted
        l0.release().unwrap();
        l1.release().unwrap();
    }

    #[test]
    fn raaas_allocations_are_scheduler_tracked() {
        let svc = RaaasService::new(hv());
        let user = svc.hv.add_user("alice");
        let lease = svc.alloc(user).unwrap();
        assert_eq!(svc.sched.in_use(user), 1);
        lease.release().unwrap();
        assert_eq!(svc.sched.in_use(user), 0);
        assert_eq!(svc.sched.usage(user).released, 1);
    }

    #[test]
    fn raaas_gang_is_atomic() {
        let svc = RaaasService::new(hv());
        let user = svc.hv.add_user("multicore");
        let gang = svc.alloc_gang(user, 4).unwrap();
        assert_eq!(gang.regions(), 4);
        assert_eq!(svc.sched.in_use(user), 4);
        // Each member programs independently (retargeted per slot).
        for i in 0..4 {
            gang.program_member(i, &mm16_bitfile()).unwrap();
        }
        gang.release().unwrap();
        assert_eq!(svc.sched.in_use(user), 0);
    }

    #[test]
    fn baaas_hides_devices_and_works() {
        if !crate::testing::artifacts_available(
            "service::baaas_hides_devices_and_works",
        ) {
            return;
        }
        let svc = BaaasService::new(hv());
        svc.hv.register_service("mm16", mm16_bitfile());
        assert_eq!(svc.catalogue(), vec!["mm16".to_string()]);
        let user = svc.hv.add_user("enduser");
        let out = svc
            .invoke(user, "mm16", &StreamConfig::matmul16(512))
            .unwrap();
        assert_eq!(out.validation_failures, 0);
        // Lease returned afterwards.
        let db = svc.hv.db.lock().unwrap();
        assert!(db.user_allocations(user).is_empty());
    }

    #[test]
    fn baaas_unknown_service() {
        let svc = BaaasService::new(hv());
        let user = svc.hv.add_user("enduser");
        assert!(matches!(
            svc.invoke(user, "ghost", &StreamConfig::matmul16(64)),
            Err(HypervisorError::UnknownService(_))
        ));
    }

    #[test]
    fn rsaas_full_cycle() {
        // paper_testbed has no RSaaS devices; use single_vc707.
        let hv = Arc::new(
            Hypervisor::boot(
                &crate::config::ClusterConfig::single_vc707(),
                VirtualClock::new(),
                crate::hypervisor::PlacementPolicy::ConsolidateFirst,
            )
            .unwrap(),
        );
        let svc = RsaasService::new(hv);
        let user = svc.hv.add_user("hwdev");
        let lease = svc.alloc(user).unwrap();
        assert!(lease.fpga().is_some());
        assert!(lease.vfpga().is_none(), "physical lease has no vFPGA");
        let bs =
            crate::bitstream::BitstreamBuilder::full("xc7vx485t", "mydesign")
                .build();
        lease.program_full(&bs).unwrap();
        lease.release().unwrap();
    }

    #[test]
    fn shared_scheduler_spans_service_models() {
        // One scheduler under both RAaaS and BAaaS façades: a tenant
        // quota of 1 concurrent vFPGA applies across both.
        let sched = Scheduler::new(hv());
        let raaas = RaaasService::with_scheduler(Arc::clone(&sched));
        let baaas = BaaasService::with_scheduler(Arc::clone(&sched));
        let user = sched.hv().add_user("capped");
        sched.set_quota(
            user,
            crate::sched::TenantQuota {
                max_concurrent: 1,
                ..Default::default()
            },
        );
        let lease = raaas.alloc(user).unwrap();
        baaas.hv.register_service("mm16", mm16_bitfile());
        // Second concurrent lease (via BAaaS) is quota-denied.
        let err = baaas
            .invoke(user, "mm16", &StreamConfig::matmul16(64))
            .unwrap_err();
        assert!(matches!(err, HypervisorError::Sched(_)), "{err}");
        lease.release().unwrap();
    }
}
