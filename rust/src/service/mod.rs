//! The three cloud service-model façades (Section III).
//!
//! These are the *user-visible* surfaces; each wraps the hypervisor
//! with exactly the rights and visibility its model grants:
//!
//! * [`RsaasService`] — full physical FPGAs (optionally inside a VM),
//!   full-bitstream freedom, the whole design flow as a cloud service;
//! * [`RaaasService`] — vFPGAs behind the RC2F framework only: users
//!   see sizes, allocate, program *partial* bitfiles through the
//!   sanity checker, and stream through the host API;
//! * [`BaaasService`] — no FPGA visibility at all: users see named
//!   services; allocation, PR and streaming happen in the background
//!   with provider bitfiles.

use std::sync::Arc;

use crate::bitstream::Bitstream;
use crate::config::ServiceModel;
use crate::hypervisor::{Hypervisor, HypervisorError};
use crate::rc2f::stream::{StreamConfig, StreamOutcome, StreamRunner};
use crate::util::ids::{AllocationId, FpgaId, UserId, VfpgaId};

/// RAaaS: vFPGA leases + framework streaming.
pub struct RaaasService {
    pub hv: Arc<Hypervisor>,
}

impl RaaasService {
    pub fn new(hv: Arc<Hypervisor>) -> RaaasService {
        RaaasService { hv }
    }

    /// Lease one vFPGA. The user learns the vFPGA id — but not the
    /// physical slot; bitfiles are retargeted transparently.
    pub fn alloc(
        &self,
        user: UserId,
    ) -> Result<(AllocationId, VfpgaId), HypervisorError> {
        let (alloc, vfpga, _, _) =
            self.hv.alloc_vfpga(user, ServiceModel::RAaaS)?;
        Ok((alloc, vfpga))
    }

    /// Program a user core. The bitfile may target any slot — it is
    /// retargeted to the actual placement (region-hiding, the
    /// future-work feature).
    pub fn program(
        &self,
        alloc: AllocationId,
        user: UserId,
        bitfile: &Bitstream,
    ) -> Result<(), HypervisorError> {
        let vfpga = self.hv.check_vfpga_lease(alloc, user)?;
        let (fpga, slot, quarters) = {
            let db = self.hv.db.lock().unwrap();
            let fpga = db
                .device_of_vfpga(vfpga)
                .ok_or(HypervisorError::BadAllocation(alloc))?
                .id;
            drop(db);
            let dev = self.hv.device(fpga)?;
            let slot = dev.slot_of[&vfpga];
            let quarters = dev
                .fpga
                .lock()
                .unwrap()
                .region(vfpga)
                .map_err(|e| HypervisorError::Device(e.to_string()))?
                .shape
                .quarters();
            (fpga, slot, quarters)
        };
        let placed =
            crate::hls::flow::DesignFlow::retarget(bitfile, slot, quarters);
        self.hv.program_vfpga(alloc, user, &placed)?;
        let _ = fpga;
        Ok(())
    }

    /// Stream a workload through the configured core.
    pub fn stream(
        &self,
        alloc: AllocationId,
        user: UserId,
        cfg: &StreamConfig,
    ) -> Result<StreamOutcome, HypervisorError> {
        let vfpga = self.hv.check_vfpga_lease(alloc, user)?;
        let fpga = {
            let db = self.hv.db.lock().unwrap();
            db.device_of_vfpga(vfpga)
                .ok_or(HypervisorError::BadAllocation(alloc))?
                .id
        };
        let api = self.hv.host_api(fpga)?;
        let session = api
            .open_session(user, vfpga)
            .map_err(|e| HypervisorError::Db(e.to_string()))?;
        session
            .stream(cfg)
            .map_err(|e| HypervisorError::Db(e.to_string()))
    }

    pub fn release(&self, alloc: AllocationId) -> Result<(), HypervisorError> {
        self.hv.release(alloc)
    }
}

/// RSaaS: whole physical devices.
pub struct RsaasService {
    pub hv: Arc<Hypervisor>,
}

impl RsaasService {
    pub fn new(hv: Arc<Hypervisor>) -> RsaasService {
        RsaasService { hv }
    }

    /// Lease a full physical FPGA.
    pub fn alloc(
        &self,
        user: UserId,
    ) -> Result<(AllocationId, FpgaId), HypervisorError> {
        let (alloc, fpga, _) = self.hv.alloc_physical(user, None)?;
        Ok((alloc, fpga))
    }

    /// Write a full user bitstream (with PCIe hot-plug handling).
    pub fn program_full(
        &self,
        alloc: AllocationId,
        user: UserId,
        bs: &Bitstream,
    ) -> Result<(), HypervisorError> {
        self.hv.program_full(alloc, user, bs)?;
        Ok(())
    }

    pub fn release(&self, alloc: AllocationId) -> Result<(), HypervisorError> {
        self.hv.release(alloc)
    }
}

/// BAaaS: named provider services, FPGAs invisible.
pub struct BaaasService {
    pub hv: Arc<Hypervisor>,
}

impl BaaasService {
    pub fn new(hv: Arc<Hypervisor>) -> BaaasService {
        BaaasService { hv }
    }

    /// What end users see: the service catalogue.
    pub fn catalogue(&self) -> Vec<String> {
        self.hv.service_names()
    }

    /// Invoke a service: the provider allocates a vFPGA in the
    /// background, programs the prebuilt bitfile, streams, releases.
    /// The caller never sees device ids.
    pub fn invoke(
        &self,
        user: UserId,
        service: &str,
        cfg: &StreamConfig,
    ) -> Result<StreamOutcome, HypervisorError> {
        let bitfile = self.hv.service_bitfile(service)?;
        let (alloc, vfpga, fpga, _) =
            self.hv.alloc_vfpga(user, ServiceModel::BAaaS)?;
        let result = (|| {
            let dev = self.hv.device(fpga)?;
            let slot = dev.slot_of[&vfpga];
            let quarters = dev
                .fpga
                .lock()
                .unwrap()
                .region(vfpga)
                .map_err(|e| HypervisorError::Device(e.to_string()))?
                .shape
                .quarters();
            let placed = crate::hls::flow::DesignFlow::retarget(
                &bitfile, slot, quarters,
            );
            self.hv.program_vfpga(alloc, user, &placed)?;
            let runner = StreamRunner::new(
                Arc::clone(&self.hv.clock),
                Arc::clone(&dev.link),
            );
            runner.run(cfg).map_err(HypervisorError::Db)
        })();
        let _ = self.hv.release(alloc);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn hv() -> Arc<Hypervisor> {
        Arc::new(Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap())
    }

    fn artifacts_present() -> bool {
        crate::runtime::artifact_dir().join("manifest.json").exists()
    }

    fn mm16_bitfile() -> Bitstream {
        crate::bitstream::BitstreamBuilder::partial("xc7vx485t", "matmul16")
            .resources(crate::fpga::resources::Resources::new(
                25_298, 41_654, 14, 80,
            ))
            .frames(crate::hls::flow::region_window(0, 1))
            .artifact("matmul16_b256")
            .build()
    }

    #[test]
    fn raaas_end_to_end() {
        if !artifacts_present() {
            return;
        }
        let svc = RaaasService::new(hv());
        let user = svc.hv.add_user("alice");
        let (alloc, _vfpga) = svc.alloc(user).unwrap();
        svc.program(alloc, user, &mm16_bitfile()).unwrap();
        let out = svc
            .stream(alloc, user, &StreamConfig::matmul16(512))
            .unwrap();
        assert_eq!(out.validation_failures, 0);
        svc.release(alloc).unwrap();
    }

    #[test]
    fn raaas_program_retargets_foreign_slot_bitfile() {
        let svc = RaaasService::new(hv());
        let user = svc.hv.add_user("alice");
        // Fill slot 0 so the next lease lands on slot 1 — the bitfile
        // below still targets slot 0's window and must be retargeted.
        let (a0, _) = svc.alloc(user).unwrap();
        let (a1, _) = svc.alloc(user).unwrap();
        svc.program(a0, user, &mm16_bitfile()).unwrap();
        svc.program(a1, user, &mm16_bitfile()).unwrap(); // would fail unretargeted
        svc.release(a0).unwrap();
        svc.release(a1).unwrap();
    }

    #[test]
    fn baaas_hides_devices_and_works() {
        if !artifacts_present() {
            return;
        }
        let svc = BaaasService::new(hv());
        svc.hv.register_service("mm16", mm16_bitfile());
        assert_eq!(svc.catalogue(), vec!["mm16".to_string()]);
        let user = svc.hv.add_user("enduser");
        let out = svc
            .invoke(user, "mm16", &StreamConfig::matmul16(512))
            .unwrap();
        assert_eq!(out.validation_failures, 0);
        // Lease returned afterwards.
        let db = svc.hv.db.lock().unwrap();
        assert!(db.user_allocations(user).is_empty());
    }

    #[test]
    fn baaas_unknown_service() {
        let svc = BaaasService::new(hv());
        let user = svc.hv.add_user("enduser");
        assert!(matches!(
            svc.invoke(user, "ghost", &StreamConfig::matmul16(64)),
            Err(HypervisorError::UnknownService(_))
        ));
    }

    #[test]
    fn rsaas_full_cycle() {
        // paper_testbed has no RSaaS devices; use single_vc707.
        let hv = Arc::new(
            Hypervisor::boot(
                &crate::config::ClusterConfig::single_vc707(),
                VirtualClock::new(),
                crate::hypervisor::PlacementPolicy::ConsolidateFirst,
            )
            .unwrap(),
        );
        let svc = RsaasService::new(hv);
        let user = svc.hv.add_user("hwdev");
        let (alloc, _fpga) = svc.alloc(user).unwrap();
        let bs =
            crate::bitstream::BitstreamBuilder::full("xc7vx485t", "mydesign")
                .build();
        svc.program_full(alloc, user, &bs).unwrap();
        svc.release(alloc).unwrap();
    }
}
