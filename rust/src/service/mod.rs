//! The three cloud service-model façades (Section III).
//!
//! These are the *user-visible* surfaces; each wraps the hypervisor
//! with exactly the rights and visibility its model grants:
//!
//! * [`RsaasService`] — full physical FPGAs (optionally inside a VM),
//!   full-bitstream freedom, the whole design flow as a cloud service;
//! * [`RaaasService`] — vFPGAs behind the RC2F framework only: users
//!   see sizes, allocate, program *partial* bitfiles through the
//!   sanity checker, and stream through the host API;
//! * [`BaaasService`] — no FPGA visibility at all: users see named
//!   services; allocation, PR and streaming happen in the background
//!   with provider bitfiles.
//!
//! Every allocation goes through the cluster [`Scheduler`]
//! ([`crate::sched`]) — quota, fair-share and reservation checks
//! apply uniformly. Interactive façade calls (RAaaS/RSaaS leases) use
//! the non-blocking fast path and may preempt batch leases; BAaaS
//! invocations are background work and admit at batch class.

use std::sync::Arc;

use crate::bitstream::Bitstream;
use crate::config::ServiceModel;
use crate::hypervisor::{Hypervisor, HypervisorError};
use crate::rc2f::stream::{StreamConfig, StreamOutcome};
use crate::sched::{RequestClass, Scheduler};
use crate::util::ids::{AllocationId, FpgaId, UserId, VfpgaId};

/// RAaaS: vFPGA leases + framework streaming.
pub struct RaaasService {
    pub hv: Arc<Hypervisor>,
    pub sched: Arc<Scheduler>,
}

impl RaaasService {
    /// Stand-alone façade with its own scheduler.
    pub fn new(hv: Arc<Hypervisor>) -> RaaasService {
        let sched = Scheduler::new(Arc::clone(&hv));
        RaaasService { hv, sched }
    }

    /// Share one cluster scheduler across façades (quotas and
    /// fair-share then apply across all service models).
    pub fn with_scheduler(sched: Arc<Scheduler>) -> RaaasService {
        RaaasService {
            hv: Arc::clone(sched.hv()),
            sched,
        }
    }

    /// Lease one vFPGA. The user learns the vFPGA id — but not the
    /// physical slot; bitfiles are retargeted transparently.
    pub fn alloc(
        &self,
        user: UserId,
    ) -> Result<(AllocationId, VfpgaId), HypervisorError> {
        let grant = self
            .sched
            .acquire_vfpga(user, ServiceModel::RAaaS, RequestClass::Interactive)
            .map_err(HypervisorError::from)?;
        let vfpga = grant.vfpga().expect("vfpga grant");
        Ok((grant.alloc, vfpga))
    }

    /// Program a user core. The bitfile may target any slot — it is
    /// retargeted to the actual placement (region-hiding, the
    /// future-work feature).
    pub fn program(
        &self,
        alloc: AllocationId,
        user: UserId,
        bitfile: &Bitstream,
    ) -> Result<(), HypervisorError> {
        let vfpga = self.hv.check_vfpga_lease(alloc, user)?;
        let placed = self.hv.retarget_for(vfpga, bitfile)?;
        self.hv.program_vfpga(alloc, user, &placed)?;
        Ok(())
    }

    /// Stream a workload through the configured core.
    pub fn stream(
        &self,
        alloc: AllocationId,
        user: UserId,
        cfg: &StreamConfig,
    ) -> Result<StreamOutcome, HypervisorError> {
        let vfpga = self.hv.check_vfpga_lease(alloc, user)?;
        let fpga = {
            let db = self.hv.db.lock().unwrap();
            db.device_of_vfpga(vfpga)
                .ok_or(HypervisorError::BadAllocation(alloc))?
                .id
        };
        let api = self.hv.host_api(fpga)?;
        let session = api
            .open_session(user, vfpga)
            .map_err(|e| HypervisorError::Db(e.to_string()))?;
        session
            .stream(cfg)
            .map_err(|e| HypervisorError::Db(e.to_string()))
    }

    pub fn release(&self, alloc: AllocationId) -> Result<(), HypervisorError> {
        self.sched.release(alloc).map_err(HypervisorError::from)
    }
}

/// RSaaS: whole physical devices.
pub struct RsaasService {
    pub hv: Arc<Hypervisor>,
    pub sched: Arc<Scheduler>,
}

impl RsaasService {
    pub fn new(hv: Arc<Hypervisor>) -> RsaasService {
        let sched = Scheduler::new(Arc::clone(&hv));
        RsaasService { hv, sched }
    }

    pub fn with_scheduler(sched: Arc<Scheduler>) -> RsaasService {
        RsaasService {
            hv: Arc::clone(sched.hv()),
            sched,
        }
    }

    /// Lease a full physical FPGA.
    pub fn alloc(
        &self,
        user: UserId,
    ) -> Result<(AllocationId, FpgaId), HypervisorError> {
        let grant = self
            .sched
            .acquire_physical(user, None, RequestClass::Interactive)
            .map_err(HypervisorError::from)?;
        Ok((grant.alloc, grant.fpga()))
    }

    /// Write a full user bitstream (with PCIe hot-plug handling).
    pub fn program_full(
        &self,
        alloc: AllocationId,
        user: UserId,
        bs: &Bitstream,
    ) -> Result<(), HypervisorError> {
        self.hv.program_full(alloc, user, bs)?;
        Ok(())
    }

    pub fn release(&self, alloc: AllocationId) -> Result<(), HypervisorError> {
        self.sched.release(alloc).map_err(HypervisorError::from)
    }
}

/// BAaaS: named provider services, FPGAs invisible.
pub struct BaaasService {
    pub hv: Arc<Hypervisor>,
    pub sched: Arc<Scheduler>,
}

impl BaaasService {
    pub fn new(hv: Arc<Hypervisor>) -> BaaasService {
        let sched = Scheduler::new(Arc::clone(&hv));
        BaaasService { hv, sched }
    }

    pub fn with_scheduler(sched: Arc<Scheduler>) -> BaaasService {
        BaaasService {
            hv: Arc::clone(sched.hv()),
            sched,
        }
    }

    /// What end users see: the service catalogue.
    pub fn catalogue(&self) -> Vec<String> {
        self.hv.service_names()
    }

    /// Invoke a service: the provider allocates a vFPGA in the
    /// background (batch class — preemptable by interactive leases),
    /// programs the prebuilt bitfile, streams, releases. The caller
    /// never sees device ids.
    pub fn invoke(
        &self,
        user: UserId,
        service: &str,
        cfg: &StreamConfig,
    ) -> Result<StreamOutcome, HypervisorError> {
        let bitfile = self.hv.service_bitfile(service)?;
        let grant = self
            .sched
            .acquire_vfpga(user, ServiceModel::BAaaS, RequestClass::Batch)
            .map_err(HypervisorError::from)?;
        let alloc = grant.alloc;
        let result = (|| {
            // Resolve placement through the lease — a preemption may
            // have relocated it between any two steps.
            let vfpga = self.hv.check_vfpga_lease(alloc, user)?;
            let placed = self.hv.retarget_for(vfpga, &bitfile)?;
            self.hv.program_vfpga(alloc, user, &placed)?;
            // Re-resolve before streaming: a preemption after PR
            // migrates the lease (and its configured design) to a new
            // region; stream where the lease lives now.
            let vfpga = self.hv.check_vfpga_lease(alloc, user)?;
            self.hv
                .stream_runner_for(vfpga)?
                .run(cfg)
                .map_err(HypervisorError::Db)
        })();
        // Always release, success or failure.
        let _ = self.sched.release(alloc);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn hv() -> Arc<Hypervisor> {
        Arc::new(Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap())
    }

    fn mm16_bitfile() -> Bitstream {
        crate::testing::mm16_partial(0)
    }

    #[test]
    fn raaas_end_to_end() {
        if !crate::testing::artifacts_available("service::raaas_end_to_end") {
            return;
        }
        let svc = RaaasService::new(hv());
        let user = svc.hv.add_user("alice");
        let (alloc, _vfpga) = svc.alloc(user).unwrap();
        svc.program(alloc, user, &mm16_bitfile()).unwrap();
        let out = svc
            .stream(alloc, user, &StreamConfig::matmul16(512))
            .unwrap();
        assert_eq!(out.validation_failures, 0);
        svc.release(alloc).unwrap();
    }

    #[test]
    fn raaas_program_retargets_foreign_slot_bitfile() {
        let svc = RaaasService::new(hv());
        let user = svc.hv.add_user("alice");
        // Fill slot 0 so the next lease lands on slot 1 — the bitfile
        // below still targets slot 0's window and must be retargeted.
        let (a0, _) = svc.alloc(user).unwrap();
        let (a1, _) = svc.alloc(user).unwrap();
        svc.program(a0, user, &mm16_bitfile()).unwrap();
        svc.program(a1, user, &mm16_bitfile()).unwrap(); // would fail unretargeted
        svc.release(a0).unwrap();
        svc.release(a1).unwrap();
    }

    #[test]
    fn raaas_allocations_are_scheduler_tracked() {
        let svc = RaaasService::new(hv());
        let user = svc.hv.add_user("alice");
        let (alloc, _) = svc.alloc(user).unwrap();
        assert_eq!(svc.sched.in_use(user), 1);
        svc.release(alloc).unwrap();
        assert_eq!(svc.sched.in_use(user), 0);
        assert_eq!(svc.sched.usage(user).released, 1);
    }

    #[test]
    fn baaas_hides_devices_and_works() {
        if !crate::testing::artifacts_available(
            "service::baaas_hides_devices_and_works",
        ) {
            return;
        }
        let svc = BaaasService::new(hv());
        svc.hv.register_service("mm16", mm16_bitfile());
        assert_eq!(svc.catalogue(), vec!["mm16".to_string()]);
        let user = svc.hv.add_user("enduser");
        let out = svc
            .invoke(user, "mm16", &StreamConfig::matmul16(512))
            .unwrap();
        assert_eq!(out.validation_failures, 0);
        // Lease returned afterwards.
        let db = svc.hv.db.lock().unwrap();
        assert!(db.user_allocations(user).is_empty());
    }

    #[test]
    fn baaas_unknown_service() {
        let svc = BaaasService::new(hv());
        let user = svc.hv.add_user("enduser");
        assert!(matches!(
            svc.invoke(user, "ghost", &StreamConfig::matmul16(64)),
            Err(HypervisorError::UnknownService(_))
        ));
    }

    #[test]
    fn rsaas_full_cycle() {
        // paper_testbed has no RSaaS devices; use single_vc707.
        let hv = Arc::new(
            Hypervisor::boot(
                &crate::config::ClusterConfig::single_vc707(),
                VirtualClock::new(),
                crate::hypervisor::PlacementPolicy::ConsolidateFirst,
            )
            .unwrap(),
        );
        let svc = RsaasService::new(hv);
        let user = svc.hv.add_user("hwdev");
        let (alloc, _fpga) = svc.alloc(user).unwrap();
        let bs =
            crate::bitstream::BitstreamBuilder::full("xc7vx485t", "mydesign")
                .build();
        svc.program_full(alloc, user, &bs).unwrap();
        svc.release(alloc).unwrap();
    }

    #[test]
    fn shared_scheduler_spans_service_models() {
        // One scheduler under both RAaaS and BAaaS façades: a tenant
        // quota of 1 concurrent vFPGA applies across both.
        let sched = Scheduler::new(hv());
        let raaas = RaaasService::with_scheduler(Arc::clone(&sched));
        let baaas = BaaasService::with_scheduler(Arc::clone(&sched));
        let user = sched.hv().add_user("capped");
        sched.set_quota(
            user,
            crate::sched::TenantQuota {
                max_concurrent: 1,
                ..Default::default()
            },
        );
        let (alloc, _) = raaas.alloc(user).unwrap();
        baaas.hv.register_service("mm16", mm16_bitfile());
        // Second concurrent lease (via BAaaS) is quota-denied.
        let err = baaas
            .invoke(user, "mm16", &StreamConfig::matmul16(64))
            .unwrap_err();
        assert!(matches!(err, HypervisorError::Sched(_)), "{err}");
        raaas.release(alloc).unwrap();
    }
}
