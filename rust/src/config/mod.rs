//! Cluster configuration.
//!
//! A deployment is described by a JSON document (parsed with the
//! in-tree [`crate::util::json`]): the management node, the FPGA
//! nodes with their boards, the service models enabled per device,
//! the sanity policy, and the calibration constants' overrides.
//!
//! `ClusterConfig::paper_testbed()` is the paper's own setup
//! (Section IV-A): two nodes, ML605 + VC707 boards, four vFPGAs per
//! device — used by the examples and benches as the default.

use crate::fpga::board::BoardKind;
use crate::util::json::Json;

/// Which service models a device may serve (Section III).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceModel {
    /// Reconfigurable Silicon as a Service — full physical FPGA.
    RSaaS,
    /// Reconfigurable Accelerators as a Service — vFPGAs via RC2F.
    RAaaS,
    /// Background Acceleration as a Service — provider services.
    BAaaS,
}

impl ServiceModel {
    pub fn name(self) -> &'static str {
        match self {
            ServiceModel::RSaaS => "rsaas",
            ServiceModel::RAaaS => "raaas",
            ServiceModel::BAaaS => "baaas",
        }
    }

    pub fn parse(s: &str) -> Option<ServiceModel> {
        match s.to_ascii_lowercase().as_str() {
            "rsaas" => Some(ServiceModel::RSaaS),
            "raaas" => Some(ServiceModel::RAaaS),
            "baaas" => Some(ServiceModel::BAaaS),
            _ => None,
        }
    }
}

/// One FPGA board entry.
#[derive(Debug, Clone, PartialEq)]
pub struct FpgaConfig {
    pub board: BoardKind,
    /// vFPGA regions the RC2F basic design carves (1, 2 or 4).
    pub vfpgas: usize,
    /// Models this device is assigned to. A device assigned to RSaaS
    /// is excluded from vFPGA allocation (Section IV-B).
    pub models: Vec<ServiceModel>,
}

/// One cluster node.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeConfig {
    pub name: String,
    pub fpgas: Vec<FpgaConfig>,
}

/// The whole deployment.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub nodes: Vec<NodeConfig>,
    /// Require provider-signed bitfiles (production BAaaS policy).
    pub require_signatures: bool,
    /// Middleware RPC overhead in ms added to remote calls
    /// (Table I: 80 ms status via RC3E vs 11 ms local → ~69 ms).
    pub rpc_overhead_ms: f64,
}

impl ClusterConfig {
    /// The paper's two-node academic testbed (Section IV-A/C).
    pub fn paper_testbed() -> ClusterConfig {
        let fpga = |board| FpgaConfig {
            board,
            vfpgas: 4,
            models: vec![ServiceModel::RAaaS, ServiceModel::BAaaS],
        };
        ClusterConfig {
            nodes: vec![
                NodeConfig {
                    name: "node-a".to_string(),
                    fpgas: vec![fpga(BoardKind::Vc707), fpga(BoardKind::Vc707)],
                },
                NodeConfig {
                    name: "node-b".to_string(),
                    fpgas: vec![fpga(BoardKind::Ml605), fpga(BoardKind::Ml605)],
                },
            ],
            require_signatures: false,
            rpc_overhead_ms: crate::paper::STATUS_RC3E_MS
                - crate::paper::STATUS_LOCAL_MS,
        }
    }

    /// Heterogeneous scheduler testbed: one VC707 serving
    /// RAaaS + BAaaS and one serving BAaaS only. Interactive RAaaS
    /// requests can land on the first device alone, so once batch
    /// work fills it the scheduler must preempt-by-migration toward
    /// the BAaaS-only device — the scenario `examples/scheduler_storm`
    /// and the `sched` test suite exercise.
    pub fn sched_testbed() -> ClusterConfig {
        ClusterConfig {
            nodes: vec![
                NodeConfig {
                    name: "node-a".to_string(),
                    fpgas: vec![FpgaConfig {
                        board: BoardKind::Vc707,
                        vfpgas: 4,
                        models: vec![
                            ServiceModel::RAaaS,
                            ServiceModel::BAaaS,
                        ],
                    }],
                },
                NodeConfig {
                    name: "node-b".to_string(),
                    fpgas: vec![FpgaConfig {
                        board: BoardKind::Vc707,
                        vfpgas: 4,
                        models: vec![ServiceModel::BAaaS],
                    }],
                },
            ],
            require_signatures: false,
            rpc_overhead_ms: 69.0,
        }
    }

    /// Single-node, single-FPGA config for the quickstart example.
    pub fn single_vc707() -> ClusterConfig {
        ClusterConfig {
            nodes: vec![NodeConfig {
                name: "node-a".to_string(),
                fpgas: vec![FpgaConfig {
                    board: BoardKind::Vc707,
                    vfpgas: 4,
                    models: vec![
                        ServiceModel::RSaaS,
                        ServiceModel::RAaaS,
                        ServiceModel::BAaaS,
                    ],
                }],
            }],
            require_signatures: false,
            rpc_overhead_ms: 69.0,
        }
    }

    /// The single-node view a federated node daemon boots: node
    /// `index`'s boards only, with every earlier node padded empty so
    /// the hypervisor assigns the daemon its cluster-wide
    /// `NodeId(index)` while its device ids stay node-local (each
    /// daemon's FPGAs number from `fpga-0`).
    pub fn for_node(&self, index: usize) -> Result<ClusterConfig, String> {
        let node = self.nodes.get(index).ok_or_else(|| {
            format!(
                "node index {index} out of range ({} nodes)",
                self.nodes.len()
            )
        })?;
        let mut nodes: Vec<NodeConfig> = (0..index)
            .map(|i| NodeConfig {
                name: format!("pad-{i}"),
                fpgas: Vec::new(),
            })
            .collect();
        nodes.push(node.clone());
        Ok(ClusterConfig {
            nodes,
            require_signatures: self.require_signatures,
            rpc_overhead_ms: self.rpc_overhead_ms,
        })
    }

    /// A device-less config for `serve --federated`: the management
    /// node owns no boards of its own; capacity arrives when node
    /// daemons register.
    pub fn management_only() -> ClusterConfig {
        ClusterConfig {
            nodes: Vec::new(),
            require_signatures: false,
            rpc_overhead_ms: 69.0,
        }
    }

    pub fn total_fpgas(&self) -> usize {
        self.nodes.iter().map(|n| n.fpgas.len()).sum()
    }

    pub fn total_vfpgas(&self) -> usize {
        self.nodes
            .iter()
            .flat_map(|n| &n.fpgas)
            .map(|f| f.vfpgas)
            .sum()
    }

    // ------------------------------------------------- JSON (de)ser

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "nodes",
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|n| {
                            Json::obj(vec![
                                ("name", Json::from(n.name.as_str())),
                                (
                                    "fpgas",
                                    Json::Arr(
                                        n.fpgas
                                            .iter()
                                            .map(|f| {
                                                Json::obj(vec![
                                                    (
                                                        "board",
                                                        Json::from(
                                                            f.board.name(),
                                                        ),
                                                    ),
                                                    (
                                                        "vfpgas",
                                                        Json::from(f.vfpgas),
                                                    ),
                                                    (
                                                        "models",
                                                        Json::Arr(
                                                            f.models
                                                                .iter()
                                                                .map(|m| {
                                                                    Json::from(
                                                                        m.name(),
                                                                    )
                                                                })
                                                                .collect(),
                                                        ),
                                                    ),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("require_signatures", Json::from(self.require_signatures)),
            ("rpc_overhead_ms", Json::from(self.rpc_overhead_ms)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ClusterConfig, String> {
        let nodes = v
            .get("nodes")
            .as_arr()
            .ok_or("config missing 'nodes'")?
            .iter()
            .map(|n| {
                let name = n.str_field("name")?.to_string();
                let fpgas = n
                    .get("fpgas")
                    .as_arr()
                    .ok_or_else(|| format!("node {name} missing fpgas"))?
                    .iter()
                    .map(|f| {
                        let board = BoardKind::parse(f.str_field("board")?)
                            .ok_or_else(|| {
                                format!("unknown board in node {name}")
                            })?;
                        let vfpgas = f.u64_field("vfpgas")? as usize;
                        if !(1..=crate::paper::MAX_VFPGAS).contains(&vfpgas) {
                            return Err(format!(
                                "vfpgas must be 1..=4, got {vfpgas}"
                            ));
                        }
                        let models = f
                            .get("models")
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|m| {
                                m.as_str().and_then(ServiceModel::parse)
                            })
                            .collect();
                        Ok(FpgaConfig {
                            board,
                            vfpgas,
                            models,
                        })
                    })
                    .collect::<Result<Vec<_>, String>>()?;
                Ok(NodeConfig { name, fpgas })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(ClusterConfig {
            nodes,
            require_signatures: v
                .get("require_signatures")
                .as_bool()
                .unwrap_or(false),
            rpc_overhead_ms: v
                .get("rpc_overhead_ms")
                .as_f64()
                .unwrap_or(69.0),
        })
    }

    /// Load from a JSON file.
    pub fn load(path: &std::path::Path) -> Result<ClusterConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| e.to_string())?;
        ClusterConfig::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_shape() {
        let c = ClusterConfig::paper_testbed();
        assert_eq!(c.nodes.len(), 2);
        assert_eq!(c.total_fpgas(), 4);
        assert_eq!(c.total_vfpgas(), 16);
        assert!((c.rpc_overhead_ms - 69.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let c = ClusterConfig::paper_testbed();
        let j = c.to_json();
        let back = ClusterConfig::from_json(&j).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn sched_testbed_is_model_asymmetric() {
        let c = ClusterConfig::sched_testbed();
        assert_eq!(c.total_fpgas(), 2);
        assert_eq!(c.total_vfpgas(), 8);
        let models: Vec<_> =
            c.nodes.iter().map(|n| n.fpgas[0].models.clone()).collect();
        assert!(models[0].contains(&ServiceModel::RAaaS));
        assert!(!models[1].contains(&ServiceModel::RAaaS));
        // Round-trips like any other config.
        let back = ClusterConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn for_node_pads_to_the_cluster_node_id() {
        let c = ClusterConfig::paper_testbed();
        let n1 = c.for_node(1).unwrap();
        // Two entries: one empty pad, then node-b's boards — so the
        // hypervisor's positional NodeId assignment yields NodeId(1).
        assert_eq!(n1.nodes.len(), 2);
        assert!(n1.nodes[0].fpgas.is_empty());
        assert_eq!(n1.nodes[1], c.nodes[1]);
        assert_eq!(n1.total_fpgas(), 2);
        assert!(c.for_node(2).is_err());
    }

    #[test]
    fn rejects_bad_vfpga_count() {
        let mut j = ClusterConfig::single_vc707().to_json();
        // Corrupt: set vfpgas to 9.
        let text = j.to_string().replace("\"vfpgas\":4", "\"vfpgas\":9");
        j = Json::parse(&text).unwrap();
        assert!(ClusterConfig::from_json(&j).is_err());
    }

    #[test]
    fn rejects_unknown_board() {
        let text = ClusterConfig::single_vc707()
            .to_json()
            .to_string()
            .replace("vc707", "zcu999");
        let j = Json::parse(&text).unwrap();
        assert!(ClusterConfig::from_json(&j).is_err());
    }

    #[test]
    fn service_model_parse_roundtrip() {
        for m in [
            ServiceModel::RSaaS,
            ServiceModel::RAaaS,
            ServiceModel::BAaaS,
        ] {
            assert_eq!(ServiceModel::parse(m.name()), Some(m));
        }
        assert_eq!(ServiceModel::parse("paas"), None);
    }

    #[test]
    fn file_load_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("rc3e_cfg_{}.json", std::process::id()));
        std::fs::write(
            &path,
            ClusterConfig::paper_testbed().to_json().to_pretty(),
        )
        .unwrap();
        let c = ClusterConfig::load(&path).unwrap();
        assert_eq!(c, ClusterConfig::paper_testbed());
        std::fs::remove_file(&path).unwrap();
    }
}
