//! # RC3E — Reconfigurable Common Cloud Computing Environment
//!
//! A full reproduction of *Knodel & Spallek, "RC3E: Provision and
//! Management of Reconfigurable Hardware Accelerators in a Cloud
//! Environment"* (2015) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate is organized exactly as DESIGN.md describes:
//!
//! * [`util`] — substrates built in-tree (JSON, virtual clock, PRNG,
//!   CLI parsing, logging, wire encoding) since the build is offline.
//! * [`config`] — typed cluster/board/calibration configuration.
//! * [`fpga`] — the simulated FPGA device model (boards, regions,
//!   resources, configuration ports, clock gating, power) with an
//!   explicit per-region lifecycle state machine (validated
//!   transitions + transition log, `docs/LIFECYCLE.md`).
//! * [`bitstream`] — full/partial bitfile format plus the sanity
//!   checker the paper lists as future work.
//! * [`bitcache`] — cluster-wide content-addressed bitstream cache +
//!   AOT compile service: cold/warm/resident program tiers,
//!   per-digest compile coalescing, admission-driven prefetch, and
//!   federated artifact fetch (`docs/BITCACHE.md`).
//! * [`pcie`] — PCIe link simulator: shared-bandwidth arbiter, device
//!   files, DMA channels, hot-plug link restoration.
//! * [`fifo`] — asynchronous FIFO with clock-domain-crossing
//!   semantics and backpressure (the RC2F streaming interface).
//! * [`runtime`] — PJRT execution engine: loads the AOT-lowered HLO
//!   artifacts and runs them as the vFPGA "user cores".
//! * [`rc2f`] — the computing framework: controller, configuration
//!   spaces (gcs/ucs), vFPGA slots and the CUDA/OpenCL-style host API.
//! * [`hls`] — the high-level-synthesis flow simulator producing
//!   partial bitstreams from core specifications.
//! * [`hypervisor`] — RC3E itself: device database, allocation for
//!   the three service models, placement, energy, and quiesce-based
//!   migration over a region pin/quiesce guard layer.
//! * [`sched`] — the cluster scheduler: the unified admission API
//!   (`AdmissionRequest` → capability `Lease` with unguessable
//!   tokens, atomic gang grants) above the hypervisor with weighted
//!   fair-share queueing + aging, per-tenant quotas, model-aware
//!   time-boxed reservations, quiesce-based preemption (atomic gang
//!   relocation, spread-vs-pack policy) and usage accounting.
//! * [`middleware`] — management-node RPC server, node agents, client
//!   library and the CLI command surface. Protocol 3: typed
//!   event-stream API (server-push subscriptions, streaming job
//!   progress, coalesced `job_wait`); protocol 1 is retired.
//! * [`cluster`] — federation: per-node daemons owning their local
//!   hypervisor + scheduler WAL, cross-node placement in the
//!   management server, heartbeat failure detection with
//!   failure-driven lease re-admission, and node-tagged federated
//!   event streams (`docs/FEDERATION.md`).
//! * [`batch`] — batch system for long-running unattended jobs, with
//!   an inline and a PR/stream-pipelined execution mode (long-lived
//!   per-worker region pair, accrual split at job boundaries).
//! * [`vm`] — virtual-machine allocation extension (RSaaS).
//! * [`service`] — RSaaS / RAaaS / BAaaS façades.
//! * [`journal`] — durability subsystem: segmented CRC-checked
//!   record log with cursors, the event-journal backing store for
//!   resumable subscriptions, and the scheduler write-ahead log that
//!   lets `rc3e serve --state DIR` re-adopt live leases after a
//!   crash (`docs/DURABILITY.md`).
//! * [`metrics`] — counters, histograms and report tables.
//! * [`testing`] — property-testing mini-framework + failure
//!   injection used across the test suite and benches.
//!
//! Python (JAX + Pallas) runs only at build time (`make artifacts`);
//! the binary serves everything from the compiled HLO artifacts.

pub mod batch;
pub mod bitcache;
pub mod bitstream;
pub mod cluster;
pub mod config;
pub mod fifo;
pub mod fpga;
pub mod hls;
pub mod hypervisor;
pub mod journal;
pub mod metrics;
pub mod middleware;
pub mod pcie;
pub mod rc2f;
pub mod runtime;
pub mod sched;
pub mod service;
pub mod testing;
pub mod util;
pub mod vm;

/// Crate version string reported by the CLI and the RPC `hello` call.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Counting allocator backing the zero-allocation assertions of the
/// descriptor-ring data plane (see [`util::memprobe`]). Pass-through
/// to the system allocator plus a thread-local counter bump.
#[global_allocator]
static GLOBAL_ALLOC_PROBE: util::memprobe::CountingAllocator =
    util::memprobe::CountingAllocator;

/// Paper constants used throughout the calibration layer.
///
/// All timing constants are the measured values of the paper's tables;
/// the simulator reproduces them through the virtual clock, and the
/// benches print paper-vs-measured rows next to each other.
pub mod paper {
    /// Table I: local RC2F status call latency.
    pub const STATUS_LOCAL_MS: f64 = 11.0;
    /// Table I: status call via the RC3E middleware.
    pub const STATUS_RC3E_MS: f64 = 80.0;
    /// Table I: full configuration (JTAG + USB), local.
    pub const CONFIG_LOCAL_S: f64 = 28.370;
    /// Table I: full configuration via RC3E.
    pub const CONFIG_RC3E_S: f64 = 29.513;
    /// Table I: partial reconfiguration, local.
    pub const PR_LOCAL_MS: f64 = 732.0;
    /// Table I: partial reconfiguration via RC3E.
    pub const PR_RC3E_MS: f64 = 912.0;
    /// Table II / Section IV-D2: Xillybus-limited PCIe throughput.
    pub const LINK_MBPS: f64 = 800.0;
    /// Table II: single-vFPGA max FIFO throughput.
    pub const FIFO_1V_MBPS: f64 = 798.0;
    /// Table II: per-core throughput with two vFPGAs.
    pub const FIFO_2V_MBPS: f64 = 397.0;
    /// Table II: per-core throughput with four vFPGAs.
    pub const FIFO_4V_MBPS: f64 = 196.0;
    /// Table II: gcs access latency with one vFPGA design (ms).
    pub const GCS_LATENCY_MS: f64 = 0.198;
    /// Table II: total config-space latency, 1 vFPGA design (ms).
    pub const UCS_1V_LATENCY_MS: f64 = 0.208;
    /// Table II: total config-space latency, 2 vFPGA design (ms).
    pub const UCS_2V_LATENCY_MS: f64 = 0.221;
    /// Table II: total config-space latency, 4 vFPGA design (ms).
    pub const UCS_4V_LATENCY_MS: f64 = 0.273;
    /// Table III: compute-bound 16x16 single-core throughput.
    pub const MM16_1C_MBPS: f64 = 509.0;
    /// Table III: link-bound 16x16 two-core per-core throughput.
    pub const MM16_2C_MBPS: f64 = 398.0;
    /// Table III: 16x16 four-core per-core throughput.
    pub const MM16_4C_MBPS: f64 = 198.0;
    /// Table III: 32x32 single-core throughput (compute bound).
    pub const MM32_1C_MBPS: f64 = 279.0;
    /// Table III: 32x32 two-core per-core throughput.
    pub const MM32_2C_MBPS: f64 = 277.0;
    /// Table III: 16x16 runtimes per core (s) for 1/2/4 cores.
    pub const MM16_RUNTIME_S: [f64; 3] = [0.73, 0.86, 1.41];
    /// Table III: 32x32 runtimes per core (s) for 1/2 cores.
    pub const MM32_RUNTIME_S: [f64; 2] = [3.27, 3.43];
    /// Section V: matrices streamed per run.
    pub const STREAM_MULTS: u64 = 100_000;
    /// Max vFPGAs per physical device (Section I / IV-A).
    pub const MAX_VFPGAS: usize = 4;
}
