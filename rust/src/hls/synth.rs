//! Synthesis model: core spec → resource + performance report.
//!
//! The constants are fitted to Table III (see module docs in
//! `hls/mod.rs`); `bench table3` prints the fit against the paper
//! rows. For matrix sizes the paper did not build, a documented
//! analytic model extrapolates: DSP = 5·N (float MAC chains), LUT/FF
//! scale with the unrolled datapath, and the streaming rate follows
//! the DSP-limited initiation interval.

use crate::fpga::resources::Resources;

/// What the user's C function computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoreKind {
    /// N×N float32 streaming matrix multiplication (the paper's
    /// Section-V example application).
    MatMul { n: usize },
    /// Identity / test loopback.
    Loopback,
    /// Elementwise a·x + y (BAaaS demo service).
    Saxpy,
    /// Per-matrix checksum reduction (monitoring demo).
    Checksum,
}

impl CoreKind {
    pub fn name(self) -> String {
        match self {
            CoreKind::MatMul { n } => format!("matmul{n}"),
            CoreKind::Loopback => "loopback".to_string(),
            CoreKind::Saxpy => "saxpy".to_string(),
            CoreKind::Checksum => "checksum".to_string(),
        }
    }
}

/// Input to the HLS flow — the "C function plus pragmas".
#[derive(Debug, Clone, PartialEq)]
pub struct CoreSpec {
    pub kind: CoreKind,
    /// Target FPGA part.
    pub part: String,
    /// Target clock in MHz (paper-era Virtex-7 designs close ~200 MHz).
    pub clock_mhz: f64,
}

impl CoreSpec {
    pub fn matmul(n: usize, part: &str) -> CoreSpec {
        CoreSpec {
            kind: CoreKind::MatMul { n },
            part: part.to_string(),
            clock_mhz: 200.0,
        }
    }

    pub fn named(kind: CoreKind, part: &str) -> CoreSpec {
        CoreSpec {
            kind,
            part: part.to_string(),
            clock_mhz: 200.0,
        }
    }

    /// The HLO artifact variant that implements this core's compute
    /// for real on the PJRT runtime, given the streaming chunk batch.
    pub fn artifact(&self, batch: usize) -> Option<String> {
        match self.kind {
            CoreKind::MatMul { n } => Some(format!("matmul{n}_b{batch}")),
            CoreKind::Loopback => Some(format!("loopback16_b{batch}")),
            CoreKind::Saxpy => Some(format!("saxpy16_b{batch}")),
            CoreKind::Checksum => Some(format!("checksum16_b{batch}")),
        }
    }
}

/// Synthesis output: area + performance of ONE core instance.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthReport {
    pub spec: CoreSpec,
    /// Marginal resources of one core instance.
    pub core_resources: Resources,
    /// One-off interface/control block shared by all instances of
    /// this core on a device (paid once).
    pub interface_resources: Resources,
    /// Streaming processing rate of the core in MB/s (input side) —
    /// the compute-bound rate before any link sharing.
    pub rate_mbps: f64,
    /// Initiation interval in cycles (reporting only).
    pub ii_cycles: u64,
}

impl SynthReport {
    /// Total area for `n` instances (Table III's rows).
    pub fn total_for(&self, n: u64) -> Resources {
        self.interface_resources.plus(self.core_resources.times(n))
    }
}

/// The synthesis model.
#[derive(Debug, Default)]
pub struct Synthesizer;

impl Synthesizer {
    pub fn new() -> Synthesizer {
        Synthesizer
    }

    /// Run "HLS synthesis" for a spec.
    pub fn synthesize(&self, spec: &CoreSpec) -> SynthReport {
        match spec.kind {
            CoreKind::MatMul { n } => self.synth_matmul(spec, n),
            CoreKind::Loopback => SynthReport {
                spec: spec.clone(),
                core_resources: Resources::new(450, 620, 1, 0),
                interface_resources: Resources::new(210, 300, 0, 0),
                // Pure wire: the FIFO (link) is always the bottleneck.
                rate_mbps: 10_000.0,
                ii_cycles: 1,
            },
            CoreKind::Saxpy => SynthReport {
                spec: spec.clone(),
                core_resources: Resources::new(2_850, 4_100, 2, 5),
                interface_resources: Resources::new(900, 1_200, 0, 0),
                rate_mbps: 1_400.0, // elementwise, near link speed
                ii_cycles: 1,
            },
            CoreKind::Checksum => SynthReport {
                spec: spec.clone(),
                core_resources: Resources::new(1_900, 2_700, 1, 2),
                interface_resources: Resources::new(700, 950, 0, 0),
                rate_mbps: 1_600.0,
                ii_cycles: 1,
            },
        }
    }

    /// Matmul calibration + extrapolation (see hls/mod.rs table).
    fn synth_matmul(&self, spec: &CoreSpec, n: usize) -> SynthReport {
        // Calibrated points from Table III.
        let (core, iface, rate) = match n {
            16 => (
                Resources::new(18_821, 35_107, 5, 80),
                Resources::new(6_477, 6_547, 9, 0),
                crate::paper::MM16_1C_MBPS,
            ),
            32 => (
                Resources::new(58_538, 119_388, 5, 160),
                Resources::new(6_173, 6_327, 9, 0),
                crate::paper::MM32_1C_MBPS,
            ),
            _ => {
                // Analytic extrapolation: the unrolled row-dot datapath
                // uses 5·N DSP48s; LUT/FF grow ~N^1.64 (fit through the
                // two calibrated points); rate follows the DSP-limited
                // initiation interval at the target clock.
                let nf = n as f64;
                let lut = (18_821.0 * (nf / 16.0).powf(1.64)) as u64;
                let ff = (35_107.0 * (nf / 16.0).powf(1.77)) as u64;
                let dsp = 5 * n as u64;
                let bram = (5.0 * (nf / 16.0).powi(2)).ceil() as u64;
                // Bytes per matrix pair: 2·N²·4; cycles per pair fitted
                // through the same two points (805 @16, 5,872 @32).
                let cycles = 805.0 * (nf / 16.0).powf(2.87);
                let rate = (2.0 * nf * nf * 4.0)
                    / (cycles / (spec.clock_mhz * 1e6))
                    / 1e6;
                (
                    Resources::new(lut, ff, bram.max(1), dsp),
                    Resources::new(6_300, 6_400, 9, 0),
                    rate,
                )
            }
        };
        let ii = (2.0 * (n as f64).powi(2) * 4.0 / rate * spec.clock_mhz)
            .round() as u64;
        SynthReport {
            spec: spec.clone(),
            core_resources: core,
            interface_resources: iface,
            rate_mbps: rate,
            ii_cycles: ii.max(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PART: &str = "xc7vx485t";

    #[test]
    fn matmul16_matches_table3_one_core() {
        let r = Synthesizer::new().synthesize(&CoreSpec::matmul(16, PART));
        let total = r.total_for(1);
        // Table III row "1 vCore": 25,298 LUT / 41,654 FF / 80 DSP / 14 BRAM
        assert_eq!(total.lut, 25_298);
        assert_eq!(total.ff, 41_654);
        assert_eq!(total.dsp, 80);
        assert_eq!(total.bram, 14);
        assert!((r.rate_mbps - 509.0).abs() < 1e-9);
    }

    #[test]
    fn matmul16_scales_close_to_table3() {
        let r = Synthesizer::new().synthesize(&CoreSpec::matmul(16, PART));
        // Table III: 2 cores 44,408 LUT; 4 cores 81,761 LUT.
        let two = r.total_for(2);
        let four = r.total_for(4);
        assert!((two.lut as f64 - 44_408.0).abs() / 44_408.0 < 0.02);
        assert!((four.lut as f64 - 81_761.0).abs() / 81_761.0 < 0.01);
        assert_eq!(two.dsp, 160);
        assert_eq!(four.dsp, 320);
    }

    #[test]
    fn matmul32_matches_table3() {
        let r = Synthesizer::new().synthesize(&CoreSpec::matmul(32, PART));
        let one = r.total_for(1);
        let two = r.total_for(2);
        assert_eq!(one.lut, 64_711);
        assert_eq!(one.ff, 125_715);
        assert_eq!(one.dsp, 160);
        assert!((two.lut as f64 - 123_249.0).abs() / 123_249.0 < 0.01);
        assert!((r.rate_mbps - 279.0).abs() < 1e-9);
    }

    #[test]
    fn extrapolated_sizes_are_monotone() {
        let s = Synthesizer::new();
        let r8 = s.synthesize(&CoreSpec::matmul(8, PART));
        let r16 = s.synthesize(&CoreSpec::matmul(16, PART));
        let r64 = s.synthesize(&CoreSpec::matmul(64, PART));
        assert!(r8.core_resources.lut < r16.core_resources.lut);
        assert!(r16.core_resources.lut < r64.core_resources.lut);
        assert_eq!(r64.core_resources.dsp, 320);
        // Bigger matrices are more compute-bound: rate drops.
        assert!(r8.rate_mbps > r16.rate_mbps);
        assert!(r16.rate_mbps > r64.rate_mbps);
    }

    #[test]
    fn artifact_binding_names() {
        assert_eq!(
            CoreSpec::matmul(16, PART).artifact(256).as_deref(),
            Some("matmul16_b256")
        );
        assert_eq!(
            CoreSpec::named(CoreKind::Loopback, PART)
                .artifact(256)
                .as_deref(),
            Some("loopback16_b256")
        );
    }

    #[test]
    fn non_matmul_cores_are_small() {
        let s = Synthesizer::new();
        for kind in [CoreKind::Loopback, CoreKind::Saxpy, CoreKind::Checksum] {
            let r = s.synthesize(&CoreSpec::named(kind, PART));
            assert!(r.core_resources.lut < 5_000, "{kind:?}");
            assert!(r.rate_mbps > crate::paper::LINK_MBPS);
        }
    }

    #[test]
    fn ii_cycles_consistent_with_rate() {
        let r = Synthesizer::new().synthesize(&CoreSpec::matmul(16, PART));
        // rate = bytes_per_pair / (ii / clock)
        let bytes_per_pair = 2.0 * 16.0 * 16.0 * 4.0;
        let implied_rate =
            bytes_per_pair / (r.ii_cycles as f64 / (200.0 * 1e6)) / 1e6;
        assert!((implied_rate - r.rate_mbps).abs() / r.rate_mbps < 0.01);
    }
}
