//! The design flow: spec → synthesis → place&route → partial bitfile.
//!
//! Fig. 5 of the paper: an application is split into a host program
//! (linked against the RC2F host library) and a C function that HLS
//! turns into a user core embedded in a vFPGA region. The flow here
//! produces a [`crate::bitstream::Bitstream`] bound to the HLO
//! artifact that executes the core's math for real.
//!
//! Region relocatability (paper future work, Section VI: "manipulate
//! the partial configuration file to utilize every feasible vFPGA
//! region") is implemented: `place_and_route` emits a *relocatable*
//! design, and [`DesignFlow::retarget`] rewrites the frame window for
//! any compatible region without re-synthesis.

use std::sync::Arc;

use super::synth::{CoreSpec, SynthReport, Synthesizer};
use crate::bitstream::{Bitstream, BitstreamBuilder, FrameRange};
use crate::fpga::region::RegionShape;
use crate::util::clock::{VirtualClock, VirtualTime};

/// Flow errors.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum FlowError {
    #[error("core '{core}' does not fit a {shape:?} region: {detail}")]
    DoesNotFit {
        core: String,
        shape: RegionShape,
        detail: String,
    },
    #[error("timing not met: needs {needed_mhz:.0} MHz, closed {closed_mhz:.0} MHz")]
    TimingFailure { needed_mhz: f64, closed_mhz: f64 },
}

/// Result of a full flow run.
#[derive(Debug, Clone)]
pub struct FlowOutput {
    pub report: SynthReport,
    pub bitstream: Bitstream,
    /// Virtual build time charged (synthesis + P&R).
    pub build_time: VirtualTime,
}

/// Frame window assigned to each quarter slot of a device (the
/// static floorplan the flow targets). Slot `i` of 4 gets
/// `[i*QUARTER_FRAMES, (i+1)*QUARTER_FRAMES)`.
pub const QUARTER_FRAMES: u64 = 4_000;

/// Frame window of a region occupying `quarters` slots at `slot`.
pub fn region_window(slot: usize, quarters: usize) -> FrameRange {
    FrameRange {
        start: slot as u64 * QUARTER_FRAMES,
        end: (slot + quarters) as u64 * QUARTER_FRAMES,
    }
}

/// The Vivado-HLS-plus-Vivado stand-in.
#[derive(Debug)]
pub struct DesignFlow {
    synth: Synthesizer,
    clock: Arc<VirtualClock>,
    /// Modeled synthesis+P&R wall time per core (charged virtually;
    /// Vivado-era flows took tens of minutes).
    build_minutes: f64,
}

impl DesignFlow {
    pub fn new(clock: Arc<VirtualClock>) -> DesignFlow {
        DesignFlow {
            synth: Synthesizer::new(),
            clock,
            build_minutes: 23.0,
        }
    }

    /// Run the full flow for one core targeting a region shape at a
    /// given quarter slot. `batch` selects the HLO artifact chunking.
    pub fn run(
        &self,
        spec: &CoreSpec,
        shape: RegionShape,
        slot: usize,
        batch: usize,
        region_capacity: crate::fpga::resources::Resources,
    ) -> Result<FlowOutput, FlowError> {
        let report = self.synth.synthesize(spec);
        let total = report.total_for(1);
        if !total.fits_in(region_capacity) {
            return Err(FlowError::DoesNotFit {
                core: spec.kind.name(),
                shape,
                detail: format!(
                    "needs {total}, region offers {region_capacity}"
                ),
            });
        }
        // P&R timing model: dense designs close slower; past ~90% LUT
        // fill of the region the clock collapses below target.
        let fill = total.utilization_of(region_capacity);
        let closed_mhz = if fill < 0.9 {
            spec.clock_mhz
        } else {
            spec.clock_mhz * (1.0 - (fill - 0.9) * 5.0)
        };
        if closed_mhz < spec.clock_mhz {
            return Err(FlowError::TimingFailure {
                needed_mhz: spec.clock_mhz,
                closed_mhz,
            });
        }
        let window = region_window(slot, shape.quarters());
        // Frames used scale with area fill inside the window.
        let used = ((window.len() as f64) * fill.max(0.05)) as u64;
        let frames = FrameRange {
            start: window.start,
            end: window.start + used.max(1),
        };
        let build_time =
            VirtualTime::from_secs_f64(self.build_minutes * 60.0);
        self.clock.advance(build_time);
        let bitstream = BitstreamBuilder::partial(&spec.part, &spec.kind.name())
            .resources(total)
            .frames(frames)
            .artifact(
                &spec
                    .artifact(batch)
                    .unwrap_or_else(|| spec.kind.name()),
            )
            .payload_len(
                (crate::fpga::board::BoardSpec::vc707()
                    .partial_bitstream_bytes(shape.fraction())
                    / 1024) as usize,
            )
            .build();
        Ok(FlowOutput {
            report,
            bitstream,
            build_time,
        })
    }

    /// Retarget a relocatable partial bitfile to another slot (the
    /// future-work feature): rewrites the frame window, preserving
    /// the design content; the sha changes because the header does.
    pub fn retarget(
        bitstream: &Bitstream,
        new_slot: usize,
        quarters: usize,
    ) -> Bitstream {
        let window = region_window(new_slot, quarters);
        let used = bitstream.meta.frames.len().min(window.len());
        let mut rebuilt = BitstreamBuilder::partial(
            &bitstream.meta.part,
            &bitstream.meta.core,
        )
        .resources(bitstream.meta.resources)
        .frames(FrameRange {
            start: window.start,
            end: window.start + used.max(1),
        })
        .payload_len(bitstream.payload.len());
        if let Some(a) = &bitstream.meta.artifact {
            rebuilt = rebuilt.artifact(a);
        }
        rebuilt.build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::resources::Resources;

    const PART: &str = "xc7vx485t";

    fn quarter_capacity() -> Resources {
        // A quarter of the VC707 PR budget (roughly).
        Resources::new(59_000, 119_000, 200, 560)
    }

    fn flow() -> (DesignFlow, Arc<VirtualClock>) {
        let clock = VirtualClock::new();
        (DesignFlow::new(Arc::clone(&clock)), clock)
    }

    #[test]
    fn matmul16_flow_produces_bound_bitstream() {
        let (flow, clock) = flow();
        let out = flow
            .run(
                &CoreSpec::matmul(16, PART),
                RegionShape::Quarter,
                0,
                256,
                quarter_capacity(),
            )
            .unwrap();
        assert_eq!(out.bitstream.meta.core, "matmul16");
        assert_eq!(
            out.bitstream.meta.artifact.as_deref(),
            Some("matmul16_b256")
        );
        assert!(region_window(0, 1).contains(out.bitstream.meta.frames));
        // Build time charged virtually.
        assert!(clock.now().as_secs_f64() > 1000.0);
    }

    #[test]
    fn oversized_core_rejected() {
        let (flow, _) = flow();
        let err = flow
            .run(
                &CoreSpec::matmul(64, PART),
                RegionShape::Quarter,
                0,
                64,
                quarter_capacity(),
            )
            .unwrap_err();
        assert!(matches!(err, FlowError::DoesNotFit { .. }));
    }

    #[test]
    fn matmul32_needs_half_region() {
        let (flow, _) = flow();
        // 32x32 (64,711 LUT) exceeds a quarter (59k) but fits a half.
        assert!(flow
            .run(
                &CoreSpec::matmul(32, PART),
                RegionShape::Quarter,
                0,
                64,
                quarter_capacity(),
            )
            .is_err());
        let half = quarter_capacity().times(2);
        let out = flow
            .run(
                &CoreSpec::matmul(32, PART),
                RegionShape::Half,
                0,
                64,
                half,
            )
            .unwrap();
        assert!(region_window(0, 2).contains(out.bitstream.meta.frames));
    }

    #[test]
    fn slots_get_disjoint_windows() {
        for a in 0..4 {
            for b in 0..4 {
                if a == b {
                    continue;
                }
                let wa = region_window(a, 1);
                let wb = region_window(b, 1);
                assert!(wa.end <= wb.start || wb.end <= wa.start);
            }
        }
    }

    #[test]
    fn retarget_moves_window_and_keeps_core() {
        let (flow, _) = flow();
        let out = flow
            .run(
                &CoreSpec::matmul(16, PART),
                RegionShape::Quarter,
                0,
                256,
                quarter_capacity(),
            )
            .unwrap();
        let moved = DesignFlow::retarget(&out.bitstream, 3, 1);
        assert!(region_window(3, 1).contains(moved.meta.frames));
        assert_eq!(moved.meta.core, out.bitstream.meta.core);
        assert_eq!(moved.meta.resources, out.bitstream.meta.resources);
        assert_eq!(moved.meta.artifact, out.bitstream.meta.artifact);
        assert_ne!(moved.sha256, out.bitstream.sha256); // header changed
        // The sanity checker accepts the retargeted file in its new slot.
        let checker = crate::bitstream::SanityChecker::new(
            crate::bitstream::SanityPolicy::research(),
        );
        assert_eq!(
            checker.check_partial(
                &moved,
                PART,
                region_window(3, 1),
                quarter_capacity()
            ),
            Ok(())
        );
    }
}
