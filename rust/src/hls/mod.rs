//! High-level-synthesis flow simulator.
//!
//! Section IV-E: the RC2F design flow takes a C function, runs Vivado
//! HLS to produce a user core, wraps it with the RC2F HDL interface
//! and emits a partial bitstream for a vFPGA region. We reproduce the
//! *flow* — spec → synthesis report → place&route → partial bitfile —
//! with a synthesis model calibrated to Table III's measured areas,
//! and bind each produced bitfile to the HLO artifact that implements
//! its compute for real (DESIGN.md §3).
//!
//! Calibration: the Table III matmul cores (Vivado HLS 2014.x-era,
//! float32, streaming interface):
//!
//! | core      | LUT/core* | FF/core* | DSP | BRAM  | rate      |
//! |-----------|-----------|----------|-----|-------|-----------|
//! | matmul16  | 18,821    | 35,107   | 80  | ~4.7  | 509 MB/s  |
//! | matmul32  | 58,538    | 119,388  | 160 | ~4.7  | 279 MB/s  |
//!
//! *marginal area per extra core; the first instance additionally
//! pays a one-off interface block (the difference between Table III's
//! 1-core row and the marginal slope).

pub mod flow;
pub mod synth;

pub use flow::{DesignFlow, FlowError, FlowOutput};
pub use synth::{CoreKind, CoreSpec, SynthReport, Synthesizer};
