//! Asynchronous FIFOs — the RC2F streaming interface.
//!
//! Section IV-D2: "Streaming access is implemented using asynchronous
//! FIFOs, which also divide the system clock from the user clock."
//! On the FPGA these are dual-clock BRAM FIFOs between the PCIe/system
//! clock domain and each vFPGA's user clock domain; here they are
//! bounded byte queues with blocking semantics and backpressure —
//! *real* queues on the Rust request path (host threads push chunks,
//! core workers pop them), not simulations.
//!
//! Capacity is expressed in bytes like the hardware's BRAM depth; a
//! full FIFO blocks the producer (the hardware asserts almost-full
//! toward the PCIe core — that is exactly the backpressure the 800
//! MB/s shared link propagates to slow cores).
//!
//! Since the descriptor-ring data plane (`docs/DATAPLANE.md`) the
//! queue carries [`Chunk`]s — either heap-owned `Vec<u8>`s (legacy
//! copy path) or pool-owned [`PooledBuf`]s handed through without
//! copying — and each FIFO can publish its occupancy and high-water
//! gauges into the metrics registry so `rc3e metrics` shows where
//! backpressure is building.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

use crate::metrics::{Gauge, Registry};
use crate::pcie::ring::PooledBuf;

/// Errors from FIFO operations.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum FifoError {
    #[error("fifo closed")]
    Closed,
    #[error("timed out after {0:?}")]
    Timeout(Duration),
    #[error("chunk of {chunk} bytes exceeds fifo capacity {capacity}")]
    ChunkTooLarge { chunk: usize, capacity: usize },
}

/// One queued payload: heap-owned bytes, or a pooled DMA slot moved
/// through the pipeline without copying.
///
/// Both variants deref to `&[u8]`, so consumers read payloads
/// uniformly; [`Chunk::into_vec`] converts for the legacy `Vec` API
/// (free for `Owned`, one copy for `Pooled`).
#[derive(Debug)]
pub enum Chunk {
    /// Heap-allocated chunk (legacy per-call allocation path).
    Owned(Vec<u8>),
    /// Pool-owned slot; dropping it recycles the slot.
    Pooled(PooledBuf),
}

impl Chunk {
    pub fn len(&self) -> usize {
        match self {
            Chunk::Owned(v) => v.len(),
            Chunk::Pooled(b) => b.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        match self {
            Chunk::Owned(v) => v,
            Chunk::Pooled(b) => b,
        }
    }

    /// Extract owned bytes; copies only when the chunk is pooled.
    pub fn into_vec(self) -> Vec<u8> {
        match self {
            Chunk::Owned(v) => v,
            Chunk::Pooled(b) => b.to_vec(),
        }
    }
}

impl std::ops::Deref for Chunk {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl From<Vec<u8>> for Chunk {
    fn from(v: Vec<u8>) -> Chunk {
        Chunk::Owned(v)
    }
}

impl From<PooledBuf> for Chunk {
    fn from(b: PooledBuf) -> Chunk {
        Chunk::Pooled(b)
    }
}

#[derive(Debug)]
struct Inner {
    queue: VecDeque<Chunk>,
    bytes: usize,
    closed: bool,
}

/// Registry gauges one FIFO publishes (see [`AsyncFifo::bind_metrics`]).
#[derive(Debug)]
struct FifoGauges {
    occupancy: Arc<Gauge>,
    high_water: Arc<Gauge>,
}

/// Occupancy statistics (status-monitor feed).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FifoStats {
    pub pushed_chunks: u64,
    pub pushed_bytes: u64,
    pub popped_chunks: u64,
    pub popped_bytes: u64,
    /// High-water mark of buffered bytes.
    pub max_occupancy: u64,
}

/// A bounded, blocking, closable byte-chunk FIFO.
#[derive(Debug)]
pub struct AsyncFifo {
    name: String,
    capacity: usize,
    inner: Mutex<Inner>,
    not_full: Condvar,
    not_empty: Condvar,
    pushed_chunks: AtomicU64,
    pushed_bytes: AtomicU64,
    popped_chunks: AtomicU64,
    popped_bytes: AtomicU64,
    max_occupancy: AtomicU64,
    gauges: OnceLock<FifoGauges>,
}

impl AsyncFifo {
    /// `capacity` is the max buffered bytes (like BRAM depth).
    pub fn new(name: &str, capacity: usize) -> Arc<AsyncFifo> {
        assert!(capacity > 0);
        Arc::new(AsyncFifo {
            name: name.to_string(),
            capacity,
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                bytes: 0,
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            pushed_chunks: AtomicU64::new(0),
            pushed_bytes: AtomicU64::new(0),
            popped_chunks: AtomicU64::new(0),
            popped_bytes: AtomicU64::new(0),
            max_occupancy: AtomicU64::new(0),
            gauges: OnceLock::new(),
        })
    }

    /// RC2F default: 2x 256 KiB chunks in flight (double buffering).
    pub fn rc2f_default(name: &str) -> Arc<AsyncFifo> {
        AsyncFifo::new(name, 512 * 1024)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Buffered bytes right now.
    pub fn occupancy(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Publish `fifo.<name>.occupancy` / `fifo.<name>.high_water`
    /// gauges into `registry`. Idempotent; the first binding wins.
    /// The FIFO name must be a valid instrument-name segment
    /// (lowercase snake_case).
    pub fn bind_metrics(&self, registry: &Registry) {
        let _ = self.gauges.get_or_init(|| FifoGauges {
            occupancy: registry.gauge(&format!("fifo.{}.occupancy", self.name)),
            high_water: registry.gauge(&format!("fifo.{}.high_water", self.name)),
        });
        self.publish_occupancy(self.occupancy());
    }

    fn publish_occupancy(&self, bytes: usize) {
        if let Some(g) = self.gauges.get() {
            g.occupancy.set(bytes as i64);
            g.high_water.fetch_max(bytes as i64);
        }
    }

    /// Blocking push with backpressure; errors if closed. Allocating
    /// legacy entry point — see [`AsyncFifo::push_chunk`] for the
    /// zero-copy path.
    pub fn push(&self, chunk: Vec<u8>) -> Result<(), FifoError> {
        self.push_chunk(Chunk::Owned(chunk))
    }

    /// Blocking push of an owned or pooled chunk with backpressure;
    /// errors if closed. Pooled chunks move through the queue without
    /// copying — this is the descriptor-ring data-plane entry point.
    pub fn push_chunk(&self, chunk: Chunk) -> Result<(), FifoError> {
        if chunk.len() > self.capacity {
            return Err(FifoError::ChunkTooLarge {
                chunk: chunk.len(),
                capacity: self.capacity,
            });
        }
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.closed {
                return Err(FifoError::Closed);
            }
            if inner.bytes + chunk.len() <= self.capacity
                || inner.queue.is_empty()
            {
                break;
            }
            inner = self.not_full.wait(inner).unwrap();
        }
        inner.bytes += chunk.len();
        self.pushed_chunks.fetch_add(1, Ordering::Relaxed);
        self.pushed_bytes
            .fetch_add(chunk.len() as u64, Ordering::Relaxed);
        self.max_occupancy
            .fetch_max(inner.bytes as u64, Ordering::Relaxed);
        let occupancy = inner.bytes;
        inner.queue.push_back(chunk);
        drop(inner);
        self.publish_occupancy(occupancy);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `Ok(None)` when the FIFO is closed *and* drained.
    /// Allocation behaviour: pooled chunks are copied into a fresh
    /// `Vec` — zero-copy consumers use [`AsyncFifo::pop_chunk`].
    pub fn pop(&self) -> Result<Option<Vec<u8>>, FifoError> {
        Ok(self.pop_chunk()?.map(Chunk::into_vec))
    }

    /// Blocking pop preserving chunk ownership; `Ok(None)` when the
    /// FIFO is closed *and* drained.
    pub fn pop_chunk(&self) -> Result<Option<Chunk>, FifoError> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(chunk) = inner.queue.pop_front() {
                inner.bytes -= chunk.len();
                self.popped_chunks.fetch_add(1, Ordering::Relaxed);
                self.popped_bytes
                    .fetch_add(chunk.len() as u64, Ordering::Relaxed);
                let occupancy = inner.bytes;
                drop(inner);
                self.publish_occupancy(occupancy);
                self.not_full.notify_one();
                return Ok(Some(chunk));
            }
            if inner.closed {
                return Ok(None);
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    /// Blocking pop into a caller-owned buffer: clears `out`, copies
    /// the next payload into it (reusing its capacity — steady state
    /// allocates nothing) and returns `Ok(true)`, or `Ok(false)` when
    /// the FIFO is closed and drained.
    pub fn pop_into(&self, out: &mut Vec<u8>) -> Result<bool, FifoError> {
        match self.pop_chunk()? {
            Some(chunk) => {
                out.clear();
                out.extend_from_slice(&chunk);
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Pop with a timeout (used by failure-injection tests and the
    /// batch system's watchdog).
    pub fn pop_timeout(
        &self,
        timeout: Duration,
    ) -> Result<Option<Vec<u8>>, FifoError> {
        let deadline = std::time::Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(chunk) = inner.queue.pop_front() {
                inner.bytes -= chunk.len();
                self.popped_chunks.fetch_add(1, Ordering::Relaxed);
                self.popped_bytes
                    .fetch_add(chunk.len() as u64, Ordering::Relaxed);
                let occupancy = inner.bytes;
                drop(inner);
                self.publish_occupancy(occupancy);
                self.not_full.notify_one();
                return Ok(Some(chunk.into_vec()));
            }
            if inner.closed {
                return Ok(None);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(FifoError::Timeout(timeout));
            }
            let (guard, res) = self
                .not_empty
                .wait_timeout(inner, deadline - now)
                .unwrap();
            inner = guard;
            if res.timed_out() && inner.queue.is_empty() && !inner.closed {
                return Err(FifoError::Timeout(timeout));
            }
        }
    }

    /// Close: producers fail, consumers drain then see `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Hard reset: drop buffered data and reopen (RC2F "full reset").
    pub fn reset(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.queue.clear();
        inner.bytes = 0;
        inner.closed = false;
        drop(inner);
        self.publish_occupancy(0);
        self.not_full.notify_all();
    }

    pub fn stats(&self) -> FifoStats {
        FifoStats {
            pushed_chunks: self.pushed_chunks.load(Ordering::Relaxed),
            pushed_bytes: self.pushed_bytes.load(Ordering::Relaxed),
            popped_chunks: self.popped_chunks.load(Ordering::Relaxed),
            popped_bytes: self.popped_bytes.load(Ordering::Relaxed),
            max_occupancy: self.max_occupancy.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn push_pop_order() {
        let f = AsyncFifo::new("t", 1024);
        f.push(vec![1, 2]).unwrap();
        f.push(vec![3]).unwrap();
        assert_eq!(f.pop().unwrap(), Some(vec![1, 2]));
        assert_eq!(f.pop().unwrap(), Some(vec![3]));
    }

    #[test]
    fn close_drains_then_none() {
        let f = AsyncFifo::new("t", 1024);
        f.push(vec![9]).unwrap();
        f.close();
        assert_eq!(f.pop().unwrap(), Some(vec![9]));
        assert_eq!(f.pop().unwrap(), None);
        assert_eq!(f.push(vec![1]), Err(FifoError::Closed));
    }

    #[test]
    fn oversized_chunk_rejected() {
        let f = AsyncFifo::new("t", 8);
        assert!(matches!(
            f.push(vec![0; 9]),
            Err(FifoError::ChunkTooLarge { .. })
        ));
    }

    #[test]
    fn backpressure_blocks_producer() {
        let f = AsyncFifo::new("t", 4);
        f.push(vec![0; 4]).unwrap();
        let f2 = Arc::clone(&f);
        let t = thread::spawn(move || {
            // This blocks until the consumer pops.
            f2.push(vec![1; 4]).unwrap();
        });
        thread::sleep(Duration::from_millis(30));
        assert!(!t.is_finished(), "producer should be blocked");
        assert_eq!(f.pop().unwrap(), Some(vec![0; 4]));
        t.join().unwrap();
        assert_eq!(f.pop().unwrap(), Some(vec![1; 4]));
    }

    #[test]
    fn pop_timeout_fires() {
        let f = AsyncFifo::new("t", 16);
        let err = f.pop_timeout(Duration::from_millis(20)).unwrap_err();
        assert!(matches!(err, FifoError::Timeout(_)));
    }

    #[test]
    fn pop_timeout_returns_data_when_present() {
        let f = AsyncFifo::new("t", 16);
        f.push(vec![5]).unwrap();
        assert_eq!(
            f.pop_timeout(Duration::from_millis(20)).unwrap(),
            Some(vec![5])
        );
    }

    #[test]
    fn producer_consumer_threads_move_all_data() {
        let f = AsyncFifo::new("t", 1024);
        let f_prod = Arc::clone(&f);
        let producer = thread::spawn(move || {
            for i in 0..100u8 {
                f_prod.push(vec![i; 64]).unwrap();
            }
            f_prod.close();
        });
        let mut total = 0usize;
        let mut chunks = 0;
        while let Some(c) = f.pop().unwrap() {
            total += c.len();
            chunks += 1;
        }
        producer.join().unwrap();
        assert_eq!(chunks, 100);
        assert_eq!(total, 6400);
        let st = f.stats();
        assert_eq!(st.pushed_bytes, 6400);
        assert_eq!(st.popped_bytes, 6400);
        assert!(st.max_occupancy <= 1024);
    }

    #[test]
    fn reset_reopens_and_clears() {
        let f = AsyncFifo::new("t", 64);
        f.push(vec![1]).unwrap();
        f.close();
        f.reset();
        assert_eq!(f.occupancy(), 0);
        f.push(vec![2]).unwrap();
        assert_eq!(f.pop().unwrap(), Some(vec![2]));
    }

    #[test]
    fn stats_track_highwater() {
        let f = AsyncFifo::new("t", 1024);
        f.push(vec![0; 100]).unwrap();
        f.push(vec![0; 200]).unwrap();
        f.pop().unwrap();
        assert_eq!(f.stats().max_occupancy, 300);
    }

    #[test]
    fn pooled_chunks_flow_without_copy() {
        let pool = crate::pcie::ring::BufferPool::new("p", 64, 2);
        let f = AsyncFifo::new("t", 1024);
        let mut buf = pool.acquire();
        buf.fill_from(&[7, 8, 9]);
        f.push_chunk(Chunk::Pooled(buf)).unwrap();
        let chunk = f.pop_chunk().unwrap().unwrap();
        assert!(matches!(chunk, Chunk::Pooled(_)));
        assert_eq!(&chunk[..], &[7, 8, 9]);
        drop(chunk);
        // Slot came back to the pool.
        assert_eq!(pool.created_total(), 1);
        let again = pool.try_acquire();
        assert!(again.is_some());
    }

    #[test]
    fn pop_into_reuses_caller_buffer() {
        let f = AsyncFifo::new("t", 1024);
        f.push(vec![1; 32]).unwrap();
        f.push(vec![2; 16]).unwrap();
        f.close();
        let mut out = Vec::with_capacity(32);
        let cap = out.capacity();
        assert!(f.pop_into(&mut out).unwrap());
        assert_eq!(out, vec![1; 32]);
        assert!(f.pop_into(&mut out).unwrap());
        assert_eq!(out, vec![2; 16]);
        assert_eq!(out.capacity(), cap);
        assert!(!f.pop_into(&mut out).unwrap());
    }

    #[test]
    fn gauges_publish_occupancy_and_high_water() {
        let reg = crate::metrics::Registry::new();
        let f = AsyncFifo::new("gauged", 1024);
        f.bind_metrics(&reg);
        f.push(vec![0; 100]).unwrap();
        f.push(vec![0; 200]).unwrap();
        let occ = reg.gauge("fifo.gauged.occupancy");
        let hw = reg.gauge("fifo.gauged.high_water");
        assert_eq!(occ.get(), 300);
        f.pop().unwrap();
        f.pop().unwrap();
        assert_eq!(occ.get(), 0);
        assert_eq!(hw.get(), 300);
    }
}
