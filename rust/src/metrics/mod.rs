//! Metrics: counters, histograms and throughput meters.
//!
//! The hypervisor monitors FPGA resources (Section IV: "resource
//! management and monitoring of FPGA resources"); this module is the
//! store those monitors write into and the benches read out of.
//! Counters are lock-free atomics so the streaming hot path never
//! takes a lock to record progress.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// A lock-free monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A lock-free gauge: a value that can move both ways (queue depth,
/// active grants). The scheduler sets it; reports read it.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicI64::new(0))
    }
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn sub(&self, n: i64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }
    /// Raise to `v` if larger (high-water marks).
    pub fn fetch_max(&self, v: i64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-boundary latency histogram: microsecond buckets at powers of
/// 2 from 1 µs to ~17 s, plus an explicit *overflow* bucket for
/// anything past the last finite bound. Lock-free recording.
///
/// Bucket `i` holds values in `(2^(i-1), 2^i]` µs; the overflow
/// bucket holds values `> 2^(BUCKETS-1)` µs, so exported quantiles
/// are never silently clamped to a fake boundary — an overflow
/// quantile reports the observed maximum instead.
#[derive(Debug)]
pub struct Histogram {
    /// `BUCKETS` finite buckets followed by one overflow slot.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

/// A point-in-time copy of one histogram, with bucket boundaries, for
/// the `metrics_export` RPC and bench JSON.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum_us: u64,
    pub max_us: u64,
    /// Inclusive upper bound of each finite bucket, in µs.
    pub bounds_us: Vec<u64>,
    /// Per-finite-bucket counts; same length as `bounds_us`.
    pub buckets: Vec<u64>,
    /// Samples above the last finite bound.
    pub overflow: u64,
}

impl Histogram {
    /// Finite buckets; index [`Self::BUCKETS`] is the overflow slot.
    const BUCKETS: usize = 25;

    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..=Self::BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Inclusive upper bound of finite bucket `i`, in µs.
    fn bound_of(i: usize) -> u64 {
        1u64 << i
    }

    /// Bucket index: the smallest `i` with `us <= bound_of(i)`;
    /// returns [`Self::BUCKETS`] (overflow) past the last finite
    /// bound.
    fn bucket_of(us: u64) -> usize {
        let ceil_log2 = (64 - (us.max(1) - 1).leading_zeros()) as usize;
        ceil_log2.min(Self::BUCKETS)
    }

    pub fn record_us(&self, us: u64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn record_secs(&self, s: f64) {
        self.record_us((s * 1e6) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from bucket boundaries (upper bound). A
    /// quantile landing in the overflow bucket reports the observed
    /// maximum rather than a fabricated bound.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().take(Self::BUCKETS).enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bound_of(i);
            }
        }
        self.max_us()
    }

    /// Copy out counts and boundary metadata.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count(),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            max_us: self.max_us(),
            bounds_us: (0..Self::BUCKETS).map(Self::bound_of).collect(),
            buckets: self.buckets[..Self::BUCKETS]
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            overflow: self.buckets[Self::BUCKETS].load(Ordering::Relaxed),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// Instrument kinds a [`Registry`] name can be bound to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstrumentKind {
    Counter,
    Gauge,
    Histogram,
}

impl InstrumentKind {
    pub fn label(self) -> &'static str {
        match self {
            InstrumentKind::Counter => "counter",
            InstrumentKind::Gauge => "gauge",
            InstrumentKind::Histogram => "histogram",
        }
    }
}

/// Whether `name` is a legal instrument name: non-empty dot-separated
/// snake_case segments (`[a-z0-9_]`), e.g. `sched.preempt.quiesce_wait`.
pub fn valid_instrument_name(name: &str) -> bool {
    !name.is_empty()
        && name.split('.').all(|seg| {
            !seg.is_empty()
                && seg
                    .chars()
                    .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        })
}

/// A point-in-time copy of every instrument in a registry.
#[derive(Debug, Clone, Default)]
pub struct RegistrySnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub histograms: Vec<(String, HistogramSnapshot)>,
}

/// Named metrics registry (one per node / per hypervisor).
///
/// Names are uniqueness-checked across instrument kinds: registering
/// `sched.wait` as both a histogram and a counter is a programmer
/// error and panics, as does a name that fails
/// [`valid_instrument_name`] — the tier-1 lint test turns either into
/// a CI failure.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn check_name(&self, name: &str, kind: InstrumentKind) {
        assert!(
            valid_instrument_name(name),
            "invalid instrument name {name:?}: must be dot-separated \
             snake_case ([a-z0-9_])"
        );
        let clash = [
            (InstrumentKind::Counter, self.counters.lock().unwrap().contains_key(name)),
            (InstrumentKind::Gauge, self.gauges.lock().unwrap().contains_key(name)),
            (InstrumentKind::Histogram, self.histograms.lock().unwrap().contains_key(name)),
        ]
        .into_iter()
        .find(|(k, present)| *present && *k != kind);
        if let Some((other, _)) = clash {
            panic!(
                "instrument name collision: {name:?} already registered \
                 as a {}, now requested as a {}",
                other.label(),
                kind.label()
            );
        }
    }

    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.check_name(name, InstrumentKind::Counter);
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Counter::new()))
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.check_name(name, InstrumentKind::Histogram);
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Histogram::new()))
            .clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.check_name(name, InstrumentKind::Gauge);
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Gauge::new()))
            .clone()
    }

    /// Copy out every instrument (the `metrics_export` payload).
    pub fn snapshot(&self) -> RegistrySnapshot {
        RegistrySnapshot {
            counters: self
                .counters
                .lock()
                .unwrap()
                .iter()
                .map(|(n, c)| (n.clone(), c.get()))
                .collect(),
            gauges: self
                .gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(n, g)| (n.clone(), g.get()))
                .collect(),
            histograms: self
                .histograms
                .lock()
                .unwrap()
                .iter()
                .map(|(n, h)| (n.clone(), h.snapshot()))
                .collect(),
        }
    }

    /// Every registered instrument name with its kind.
    pub fn names(&self) -> Vec<(String, InstrumentKind)> {
        let mut out: Vec<(String, InstrumentKind)> = Vec::new();
        for n in self.counters.lock().unwrap().keys() {
            out.push((n.clone(), InstrumentKind::Counter));
        }
        for n in self.gauges.lock().unwrap().keys() {
            out.push((n.clone(), InstrumentKind::Gauge));
        }
        for n in self.histograms.lock().unwrap().keys() {
            out.push((n.clone(), InstrumentKind::Histogram));
        }
        out.sort();
        out
    }

    /// Render all metrics as a report (CLI `rc3e stats`).
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name} = {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name} = {} (gauge)\n", g.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "{name}: n={} mean={:.1}us p50<={}us p99<={}us max={}us\n",
                h.count(),
                h.mean_us(),
                h.quantile_us(0.5),
                h.quantile_us(0.99),
                h.max_us()
            ));
        }
        out
    }
}

/// Throughput meter: bytes over a time window.
#[derive(Debug, Default)]
pub struct Throughput {
    bytes: AtomicU64,
}

impl Throughput {
    pub fn new() -> Throughput {
        Throughput::default()
    }
    pub fn add_bytes(&self, n: u64) {
        self.bytes.fetch_add(n, Ordering::Relaxed);
    }
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
    /// MB/s given an elapsed wall/virtual duration in seconds.
    pub fn mbps(&self, secs: f64) -> f64 {
        if secs <= 0.0 {
            0.0
        } else {
            self.bytes() as f64 / 1e6 / secs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::new();
        for us in [100, 200, 400, 800] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_us() - 375.0).abs() < 1e-9);
        assert_eq!(h.max_us(), 800);
        assert!(h.quantile_us(0.5) >= 200);
        assert!(h.quantile_us(1.0) >= 800);
    }

    #[test]
    fn histogram_bucket_monotone() {
        assert!(Histogram::bucket_of(1) < Histogram::bucket_of(1000));
        assert!(
            Histogram::bucket_of(1000) < Histogram::bucket_of(1_000_000)
        );
        // Values in (2^(i-1), 2^i] land in bucket i.
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 2);
        // The last finite bound is inclusive; past it is overflow.
        let last = Histogram::bound_of(Histogram::BUCKETS - 1);
        assert_eq!(Histogram::bucket_of(last), Histogram::BUCKETS - 1);
        assert_eq!(Histogram::bucket_of(last + 1), Histogram::BUCKETS);
        assert_eq!(Histogram::bucket_of(u64::MAX), Histogram::BUCKETS);
    }

    #[test]
    fn histogram_snapshot_exposes_bounds_and_overflow() {
        let h = Histogram::new();
        h.record_us(3); // bucket 2 (bound 4)
        h.record_us(100_000_000_000); // ~28 h: overflow
        let s = h.snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.bounds_us.len(), s.buckets.len());
        assert_eq!(s.bounds_us[0], 1);
        assert_eq!(*s.bounds_us.last().unwrap(), 1 << 24);
        assert_eq!(s.buckets[2], 1);
        assert_eq!(s.overflow, 1);
        assert_eq!(s.max_us, 100_000_000_000);
        assert_eq!(s.buckets.iter().sum::<u64>() + s.overflow, s.count);
        // An overflow quantile reports the observed max, not a
        // fabricated bucket bound.
        assert_eq!(h.quantile_us(1.0), 100_000_000_000);
    }

    #[test]
    fn registry_rejects_bad_names() {
        assert!(valid_instrument_name("sched.preempt.quiesce_wait"));
        assert!(!valid_instrument_name("Sched.wait"));
        assert!(!valid_instrument_name("sched..wait"));
        assert!(!valid_instrument_name("sched.wait-ms"));
        assert!(!valid_instrument_name(""));
        let bad = std::panic::catch_unwind(|| {
            Registry::new().counter("Not-Snake");
        });
        assert!(bad.is_err(), "invalid name accepted");
    }

    #[test]
    fn registry_rejects_kind_collisions() {
        let r = Registry::new();
        r.counter("hv.pr").inc();
        let clash = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                r.histogram("hv.pr");
            }),
        );
        assert!(clash.is_err(), "kind collision accepted");
        // Same kind re-registration stays fine.
        assert_eq!(r.counter("hv.pr").get(), 1);
    }

    #[test]
    fn registry_snapshot_and_names() {
        let r = Registry::new();
        r.counter("a.count").add(3);
        r.gauge("b.depth").set(-2);
        r.histogram("c.wait").record_us(7);
        let s = r.snapshot();
        assert_eq!(s.counters, vec![("a.count".to_string(), 3)]);
        assert_eq!(s.gauges, vec![("b.depth".to_string(), -2)]);
        assert_eq!(s.histograms.len(), 1);
        assert_eq!(s.histograms[0].1.count, 1);
        let names = r.names();
        assert_eq!(names.len(), 3);
        assert!(names
            .iter()
            .any(|(n, k)| n == "c.wait" && *k == InstrumentKind::Histogram));
    }

    #[test]
    fn histogram_record_secs() {
        let h = Histogram::new();
        h.record_secs(0.001);
        assert_eq!(h.count(), 1);
        assert!((h.mean_us() - 1000.0).abs() < 1.0);
    }

    #[test]
    fn registry_reuses_instruments() {
        let r = Registry::new();
        r.counter("allocs").inc();
        r.counter("allocs").inc();
        assert_eq!(r.counter("allocs").get(), 2);
        r.histogram("lat").record_us(5);
        let report = r.report();
        assert!(report.contains("allocs = 2"));
        assert!(report.contains("lat: n=1"));
    }

    #[test]
    fn gauge_moves_both_ways() {
        let r = Registry::new();
        let g = r.gauge("queue.depth");
        g.set(5);
        g.add(3);
        g.sub(6);
        assert_eq!(r.gauge("queue.depth").get(), 2);
        assert!(r.report().contains("queue.depth = 2 (gauge)"));
    }

    #[test]
    fn throughput_math() {
        let t = Throughput::new();
        t.add_bytes(200_000_000);
        assert!((t.mbps(2.0) - 100.0).abs() < 1e-9);
        assert_eq!(t.mbps(0.0), 0.0);
    }

    #[test]
    fn empty_histogram_quantiles() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }
}
