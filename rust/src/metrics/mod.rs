//! Metrics: counters, histograms and throughput meters.
//!
//! The hypervisor monitors FPGA resources (Section IV: "resource
//! management and monitoring of FPGA resources"); this module is the
//! store those monitors write into and the benches read out of.
//! Counters are lock-free atomics so the streaming hot path never
//! takes a lock to record progress.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;

/// A lock-free monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A lock-free gauge: a value that can move both ways (queue depth,
/// active grants). The scheduler sets it; reports read it.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicI64::new(0))
    }
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn sub(&self, n: i64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-boundary latency histogram (microsecond buckets, powers of 2
/// from 1 µs to ~17 s). Lock-free recording.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Histogram {
    const BUCKETS: usize = 25;

    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..Self::BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        ((64 - us.max(1).leading_zeros()) as usize).min(Self::BUCKETS - 1)
    }

    pub fn record_us(&self, us: u64) {
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn record_secs(&self, s: f64) {
        self.record_us((s * 1e6) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << i;
            }
        }
        self.max_us()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// Named metrics registry (one per node / per hypervisor).
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Counter::new()))
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Histogram::new()))
            .clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Gauge::new()))
            .clone()
    }

    /// Render all metrics as a report (CLI `rc3e stats`).
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name} = {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name} = {} (gauge)\n", g.get()));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "{name}: n={} mean={:.1}us p50<={}us p99<={}us max={}us\n",
                h.count(),
                h.mean_us(),
                h.quantile_us(0.5),
                h.quantile_us(0.99),
                h.max_us()
            ));
        }
        out
    }
}

/// Throughput meter: bytes over a time window.
#[derive(Debug, Default)]
pub struct Throughput {
    bytes: AtomicU64,
}

impl Throughput {
    pub fn new() -> Throughput {
        Throughput::default()
    }
    pub fn add_bytes(&self, n: u64) {
        self.bytes.fetch_add(n, Ordering::Relaxed);
    }
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
    /// MB/s given an elapsed wall/virtual duration in seconds.
    pub fn mbps(&self, secs: f64) -> f64 {
        if secs <= 0.0 {
            0.0
        } else {
            self.bytes() as f64 / 1e6 / secs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::new();
        for us in [100, 200, 400, 800] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_us() - 375.0).abs() < 1e-9);
        assert_eq!(h.max_us(), 800);
        assert!(h.quantile_us(0.5) >= 200);
        assert!(h.quantile_us(1.0) >= 800);
    }

    #[test]
    fn histogram_bucket_monotone() {
        assert!(Histogram::bucket_of(1) < Histogram::bucket_of(1000));
        assert!(
            Histogram::bucket_of(1000) < Histogram::bucket_of(1_000_000)
        );
        // Saturates at the top bucket.
        assert_eq!(Histogram::bucket_of(u64::MAX), Histogram::BUCKETS - 1);
    }

    #[test]
    fn histogram_record_secs() {
        let h = Histogram::new();
        h.record_secs(0.001);
        assert_eq!(h.count(), 1);
        assert!((h.mean_us() - 1000.0).abs() < 1.0);
    }

    #[test]
    fn registry_reuses_instruments() {
        let r = Registry::new();
        r.counter("allocs").inc();
        r.counter("allocs").inc();
        assert_eq!(r.counter("allocs").get(), 2);
        r.histogram("lat").record_us(5);
        let report = r.report();
        assert!(report.contains("allocs = 2"));
        assert!(report.contains("lat: n=1"));
    }

    #[test]
    fn gauge_moves_both_ways() {
        let r = Registry::new();
        let g = r.gauge("queue.depth");
        g.set(5);
        g.add(3);
        g.sub(6);
        assert_eq!(r.gauge("queue.depth").get(), 2);
        assert!(r.report().contains("queue.depth = 2 (gauge)"));
    }

    #[test]
    fn throughput_math() {
        let t = Throughput::new();
        t.add_bytes(200_000_000);
        assert!((t.mbps(2.0) - 100.0).abs() < 1e-9);
        assert_eq!(t.mbps(0.0), 0.0);
    }

    #[test]
    fn empty_histogram_quantiles() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }
}
